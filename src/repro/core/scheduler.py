"""Event-driven multi-job simulator + the BACE-Pipe scheduling policy.

The simulator advances a global clock through job arrivals and completions.
At every decision point the active policy (BACE-Pipe, a baseline, or an
ablation) orders the pending queue and attempts placements; placed jobs
reserve GPUs (Eq. 5) and link bandwidth (Eq. 6) until completion.  All
policies are work-conserving: a job that cannot be placed is skipped, not a
barrier — HoL blocking in this model is *resource* occupancy, exactly the
phenomenon the paper analyses.

Two engines share the identical event loop (see DESIGN.md):

* ``vectorized`` (default) — pending-queue invariants (``E_j(1)``, ``b_j`` at
  ``K*``, submit keys) live in aligned arrays inside ``_PendingLedger``; a
  successful placement triggers an incremental re-rank (only ``alpha`` and
  the two normalization maxima change, an O(n) recombine + O(n log n)
  ``lexsort``) instead of the seed's recompute-everything re-order.
* ``legacy`` — the seed engine preserved verbatim (``legacy.py``): full
  policy re-order with per-call invariant recomputation.  Kept as the parity
  reference and the benchmark baseline.

Both engines produce bit-identical ``SimulationResult``s on static
scenarios; the engine-parity test enforces this for every policy and
ablation.

Dynamic environments (``trace=``) add two event families on top of arrivals
and completions — ``bandwidth_change`` (a ``BandwidthTrace`` breakpoint
rescaling link capacities and/or electricity prices) and preemptive
migration when a drop strands a running pipeline (see the ``Simulator``
docstring for the exact semantics and tiebreak order).  Dynamic scenarios
run on the vectorized engine only and carry their own determinism
guarantee: same cluster, profiles, trace, and policy ⇒ an identical
``SimulationResult``, event log included.

Cost accounting is *settle-on-event* (``core/accounting.py``): every live
segment owns a ``SegmentLedger`` that splits at each price breakpoint
touching an occupied region and accrues per sub-interval at the then-current
regional prices; completion and preemption settle the accrued value instead
of charging a start-time projection and backing it out.  A never-repriced
segment settles to its placement-time projection bit-exactly, which is what
keeps static scenarios (and the legacy engine, sharing this event loop)
byte-identical to the seed.  On top of the ledger sits *price-aware
voluntary migration* (``voluntary_migration_threshold=``): at a price
breakpoint a running job whose remaining-work cost on its current placement
exceeds the best feasible live-priced alternative by more than the threshold
checkpoints and re-queues (event kind ``"migrate"``; counted separately from
forced ``"preempt"`` evictions).

Timing backend: every completion projection, remaining-work estimate, and
voluntary-migration probe prices placements through ``timing.iteration_time``
— the ``TimingModel`` seam.  A job whose ``JobSpec.timing_model`` is
``"microplan"`` is therefore scheduled against the discrete per-microbatch
timeline of its ``pipeline_schedule`` (``core/microplan``) end to end, while
the default ``analytic`` spec keeps the seed's closed-form Eq. (1) path
bit-identical (golden-trace and engine-parity surface).
"""

from __future__ import annotations

import abc
import dataclasses
import heapq
from typing import TYPE_CHECKING, Dict, List, Optional, Sequence, Tuple

import numpy as np

if TYPE_CHECKING:
    # The one sanctioned obs import in core/ (reprolint RPL601): the typing
    # protocol seam.  Never imported at runtime — tracing hooks are duck
    # calls guarded by ``recorder is not None``.
    from repro.obs.protocol import TraceRecorder

from .accounting import SegmentLedger
from .allocator import cost_min_allocate
from .cluster import BandwidthTrace, ClusterState
from .job import JobProfile
from .kernels_decide import (
    DECISION_BACKENDS,
    DEFAULT_DECISION_BACKEND,
    resolve_backend,
)
from .legacy import legacy_find_placement, legacy_order_by_priority
from .pathfinder import find_placement
from .placement import Placement
from .priority import _score_vector, order_by_priority, rank_order
from .timing import iteration_time, placement_power_rate

#: Lost progress per preemption (s): checkpoint write + restore + pipeline
#: re-warm.  Charged as extra execution time (GPUs are held while restoring,
#: so Eq. 4 cost accrues for it too).
DEFAULT_RESTART_PENALTY_S = 600.0


def _reserve_placement(cluster: ClusterState, placement: Placement) -> None:
    """Reserve a placement's GPUs — per (region, type) when the grant is
    typed (heterogeneous clusters), by region otherwise."""
    if placement.typed_alloc:
        cluster.reserve_gpus_typed(placement.typed_alloc)
    else:
        cluster.reserve_gpus(placement.alloc)


def _release_placement(cluster: ClusterState, placement: Placement) -> None:
    if placement.typed_alloc:
        cluster.release_gpus_typed(placement.typed_alloc)
    else:
        cluster.release_gpus(placement.alloc)


class SchedulingPolicy(abc.ABC):
    """Order + place: the two decisions every scheduler makes.

    ``strict_fcfs``: classic FIFO semantics — when the job at the head of the
    (policy-ordered) queue cannot be placed, the scheduling pass stops; jobs
    behind it wait.  This is how the paper's FCFS baselines exhibit HoL
    blocking.  BACE-Pipe instead *re-orders* the queue every event (Eq. 12),
    which subsumes skipping a stuck job.

    ``ordering_kind`` declares the ordering rule ("priority" for Eq. 12,
    "fcfs" for submit-time order, None for anything else) so the vectorized
    engine can maintain the rank incrementally; policies with ``None`` fall
    back to ``order()`` every pass.

    ``decision_backend`` names the kernel backend placement decisions should
    run on (``core/kernels_decide``); the ``Simulator`` stamps it from its
    own ``decision_backend=`` argument, and policies built on the Pathfinder
    pass it through to ``find_placement``.  Policies that ignore it (the
    baselines' region-local placers have no batched kernels) are unaffected.
    """

    name: str = "base"
    strict_fcfs: bool = False
    ordering_kind: Optional[str] = None
    decision_backend: str = DEFAULT_DECISION_BACKEND
    #: Optional out-of-band decision tracer (``repro.obs`` protocol seam).
    #: Stamped by the ``Simulator`` from its ``recorder=`` argument, exactly
    #: like ``decision_backend``; policies built on the Pathfinder pass it
    #: through to ``find_placement`` so per-candidate admission outcomes are
    #: recorded.  ``None`` (default) keeps every traced branch dead.
    trace_recorder: Optional["TraceRecorder"] = None

    @abc.abstractmethod
    def order(
        self, pending: Sequence[JobProfile], cluster: ClusterState, now: float
    ) -> List[JobProfile]:
        ...

    @abc.abstractmethod
    def place(
        self, profile: JobProfile, cluster: ClusterState
    ) -> Optional[Placement]:
        ...

    # Seed-engine hooks: the legacy engine routes through these so the
    # reference path keeps the seed's exact implementations (and costs).
    def legacy_order(
        self, pending: Sequence[JobProfile], cluster: ClusterState, now: float
    ) -> List[JobProfile]:
        return self.order(pending, cluster, now)

    def legacy_place(
        self, profile: JobProfile, cluster: ClusterState
    ) -> Optional[Placement]:
        return self.place(profile, cluster)


def fcfs_order(
    pending: Sequence[JobProfile], cluster: ClusterState, now: float
) -> List[JobProfile]:
    return sorted(pending, key=lambda p: (p.spec.submit_time, p.spec.job_id))


class BACEPipePolicy(SchedulingPolicy):
    """The paper's scheduler: dynamic priority -> Pathfinder -> Cost-Min."""

    name = "bace-pipe"

    def __init__(self, *, use_priority: bool = True) -> None:
        self.use_priority = use_priority
        self.ordering_kind = "priority" if use_priority else "fcfs"

    def order(self, pending, cluster, now):
        if self.use_priority:
            return order_by_priority(pending, cluster)
        return fcfs_order(pending, cluster, now)

    def place(self, profile, cluster):
        return find_placement(
            profile,
            cluster,
            allocator=cost_min_allocate,
            backend=self.decision_backend,
            recorder=self.trace_recorder,
        )

    def legacy_order(self, pending, cluster, now):
        if self.use_priority:
            return legacy_order_by_priority(pending, cluster)
        return fcfs_order(pending, cluster, now)

    def legacy_place(self, profile, cluster):
        return legacy_find_placement(profile, cluster, allocator=cost_min_allocate)


# --------------------------------------------------------------------- result
@dataclasses.dataclass
class JobRecord:
    """One *run segment* of a job.  Static scenarios have exactly one segment
    per job; under the dynamic engine a preempted job leaves one record per
    aborted segment (``preempted=True``, ``finish`` = preemption time) plus
    the final completed one."""

    job_id: int
    model_name: str
    submit: float
    start: float
    finish: float
    placement: Placement
    iteration_seconds: float
    preempted: bool = False
    #: Settled Eq. 4 cost of this segment (piecewise over env breakpoints;
    #: always >= 0).  Not serialized by ``to_jsonable`` — the golden traces
    #: pin the per-job ``costs`` dict, of which segment costs are the
    #: partition.
    cost: float = 0.0

    @property
    def wait(self) -> float:  # W_j
        return self.start - self.submit

    @property
    def execution(self) -> float:  # E_j
        return self.finish - self.start

    @property
    def jct(self) -> float:  # T_j = W_j + E_j
        return self.finish - self.submit


@dataclasses.dataclass
class SimulationResult:
    policy: str
    records: List[JobRecord]
    costs: Dict[int, float]
    makespan: float
    #: Per-job migration count, forced *and* voluntary (jobs never migrated
    #: are absent) — one entry per aborted segment.
    migrations: Dict[int, int] = dataclasses.field(default_factory=dict)
    #: Per-job total preempted-to-restart stall time (s); same keys as
    #: ``migrations``.
    stall_seconds: Dict[int, float] = dataclasses.field(default_factory=dict)
    #: Per-job *voluntary* (price-reactive) migration count; a subset of
    #: ``migrations``.  Forced (Eq. 6 eviction) counts are the difference —
    #: see ``forced_migrations``.
    voluntary_migrations: Dict[int, int] = dataclasses.field(
        default_factory=dict
    )
    #: Chronological event log: (time, kind, id) with kind in {"arrival",
    #: "start", "preempt" (forced), "migrate" (voluntary), "complete",
    #: "env"}; id is the job id (or the trace update index for "env").  This
    #: is what the golden-trace tests pin.
    events: List[Tuple[float, str, int]] = dataclasses.field(
        default_factory=list
    )
    #: Fleet size (total GPUs) at simulation start; denominator of the
    #: ``gpu_utilization`` summary line.  ``None`` for hand-built results.
    cluster_gpus: Optional[int] = None

    #: Serialization schema version for ``to_jsonable`` — bumped to 2 when
    #: ``schema_version``/``cluster_gpus`` keys were added.
    SCHEMA_VERSION = 2

    @property
    def completed_records(self) -> List[JobRecord]:
        """Final (non-preempted) segment of every job."""
        return [r for r in self.records if not r.preempted]

    @property
    def average_jct(self) -> float:
        done = self.completed_records
        return sum(r.jct for r in done) / len(done)

    @property
    def total_cost(self) -> float:
        return sum(sorted(self.costs.values()))

    @property
    def total_migrations(self) -> int:
        return sum(sorted(self.migrations.values()))

    @property
    def forced_migrations(self) -> Dict[int, int]:
        """Per-job Eq. 6 (bandwidth-drop) eviction counts:
        ``migrations - voluntary_migrations``."""
        out = {}
        for job_id, n in self.migrations.items():
            forced = n - self.voluntary_migrations.get(job_id, 0)
            if forced:
                out[job_id] = forced
        return out

    @property
    def total_voluntary_migrations(self) -> int:
        return sum(sorted(self.voluntary_migrations.values()))

    @property
    def total_stall_seconds(self) -> float:
        return sum(sorted(self.stall_seconds.values()))

    @property
    def average_hol_wait(self) -> float:
        """Mean queue (head-of-line) wait W_j to *first* start, per job."""
        first_start: Dict[int, float] = {}
        submit: Dict[int, float] = {}
        for r in self.records:
            if r.job_id not in first_start or r.start < first_start[r.job_id]:
                first_start[r.job_id] = r.start
                submit[r.job_id] = r.submit
        if not first_start:
            return 0.0
        waits = [first_start[j] - submit[j] for j in sorted(first_start)]
        return sum(waits) / len(waits)

    @property
    def gpu_utilization(self) -> Optional[float]:
        """GPU-seconds held by job segments over the fleet's capacity
        (``cluster_gpus`` × makespan); ``None`` when the fleet size is
        unknown or nothing ran."""
        if not self.cluster_gpus or self.makespan <= 0.0:
            return None
        used = sum(
            r.execution * r.placement.total_gpus
            for r in sorted(self.records, key=lambda r: (r.job_id, r.start))
        )
        return used / (self.cluster_gpus * self.makespan)

    def summary(self) -> str:
        extra = (
            f", migrations={self.total_migrations}"
            f" ({self.total_voluntary_migrations} voluntary)"
            if self.migrations
            else ""
        )
        extra += f", hol_wait={self.average_hol_wait / 3600.0:.3f} h"
        util = self.gpu_utilization
        if util is not None:
            extra += f", util={util:.1%}"
        return (
            f"{self.policy}: avg_jct={self.average_jct / 3600.0:.3f} h, "
            f"total_cost=${self.total_cost:.2f}, "
            f"makespan={self.makespan / 3600.0:.3f} h{extra}"
        )

    def to_jsonable(self) -> Dict:
        """Canonical JSON form (sorted keys, full float precision) for the
        golden-trace regression tests and benchmark dumps.  The
        ``voluntary_migrations`` key only appears when non-empty so scenarios
        that never migrate voluntarily (every static scenario, every
        price-free trace) keep their historical serialization byte-for-byte;
        per-segment ``JobRecord.cost`` is intentionally not serialized (the
        per-job ``costs`` dict it partitions is).  ``schema_version`` stamps
        the serialization contract (2 = added ``schema_version`` +
        ``cluster_gpus``); ``cluster_gpus`` appears when known."""
        out = {
            "schema_version": self.SCHEMA_VERSION,
            "policy": self.policy,
            "makespan": self.makespan,
            "costs": {str(j): c for j, c in sorted(self.costs.items())},
            "migrations": {
                str(j): n for j, n in sorted(self.migrations.items())
            },
            "stall_seconds": {
                str(j): s for j, s in sorted(self.stall_seconds.items())
            },
            "records": [self._record_jsonable(r) for r in self.records],
            "events": [[t, kind, i] for t, kind, i in self.events],
        }
        if self.voluntary_migrations:
            out["voluntary_migrations"] = {
                str(j): n
                for j, n in sorted(self.voluntary_migrations.items())
            }
        if self.cluster_gpus is not None:
            out["cluster_gpus"] = self.cluster_gpus
        return out

    @staticmethod
    def _record_jsonable(r: "JobRecord") -> Dict:
        placement = {
            "path": list(r.placement.path),
            "alloc": {
                reg: int(n) for reg, n in sorted(r.placement.alloc.items())
            },
            "comm_times": list(r.placement.comm_times),
            "reserved_bw": {
                f"{u}->{v}": b
                for (u, v), b in sorted(r.placement.reserved_bw.items())
            },
        }
        # Typed grants serialize only when present, so single-type clusters
        # keep their historical (golden-pinned) serialization byte-for-byte.
        if r.placement.typed_alloc:
            placement["typed_alloc"] = {
                reg: {t: int(n) for t, n in sorted(types.items())}
                for reg, types in sorted(r.placement.typed_alloc.items())
            }
        return {
            "job_id": r.job_id,
            "model_name": r.model_name,
            "submit": r.submit,
            "start": r.start,
            "finish": r.finish,
            "preempted": r.preempted,
            "iteration_seconds": r.iteration_seconds,
            "placement": placement,
        }


# --------------------------------------------------------------- pending set
class _PendingLedger:
    """Pending queue with its scheduling invariants held in aligned arrays.

    Per-job quantities that never change while a job waits — ``E_j(1)``,
    ``b_j`` at ``K*(cluster)``, submit time, id, the ``min_gpus`` memory
    floor — are gathered once on arrival into preallocated numpy arrays
    (amortized O(1); capacity doubles on growth, so a 10k-job queue never
    re-gathers or converts Python lists per pass).  A re-rank after a
    placement therefore only recombines the arrays under the new ``alpha``
    and normalization maxima: O(n) numpy arithmetic + one O(n log n) lexsort,
    versus the seed's O(n · K) invariant recomputation per pass.  Removal is
    a swap-pop, keeping the arrays dense.

    ``ordered(..., gpu_floor=...)`` additionally masks out jobs whose memory
    floor exceeds the cluster-wide free-GPU total *before* sorting and
    materializing profiles.  The mask is exact, not heuristic: the engine
    discards any placement with ``total_gpus < min_gpus``, and no placement
    can exceed the free total, so a masked job's ``place()`` attempt could
    never have started it — skipping the attempt is unobservable (scores
    still normalize over the *full* pending queue, per Eqs. 9–10).  On a
    saturated cluster this turns each no-progress pass from O(pending)
    Python placement probes into one numpy mask.
    """

    _ARRAYS = ("_singles", "_demands", "_submits", "_ids", "_min_gpus")

    def __init__(self, cluster_cap: int) -> None:
        self._cap = cluster_cap
        self._profiles: List[JobProfile] = []
        self._n = 0
        self._singles = np.empty(16, dtype=np.float64)
        self._demands = np.empty(16, dtype=np.float64)
        self._submits = np.empty(16, dtype=np.float64)
        self._ids = np.empty(16, dtype=np.int64)
        self._min_gpus = np.empty(16, dtype=np.int64)
        self._pos: Dict[int, int] = {}

    def __len__(self) -> int:
        return self._n

    def add(self, profile: JobProfile) -> None:
        i = self._n
        if i == len(self._ids):
            for name in self._ARRAYS:
                arr = getattr(self, name)
                grown = np.empty(2 * len(arr), dtype=arr.dtype)
                grown[:i] = arr
                setattr(self, name, grown)
        job_id = profile.spec.job_id
        self._pos[job_id] = i
        self._profiles.append(profile)
        self._singles[i] = profile.single_gpu_execution()
        self._demands[i] = profile.demand_at_cap(self._cap)
        self._submits[i] = profile.spec.submit_time
        self._ids[i] = job_id
        self._min_gpus[i] = profile.min_gpus
        self._n = i + 1

    def set_cap(self, cluster_cap: int) -> None:
        """Re-anchor the cached ``b_j`` at ``K*(cluster_cap)``: a spot
        reclaim moves ``total_gpus`` mid-run, and the Eq. 10 demands were
        gathered against the old fleet size.  O(n) over the pending queue,
        and the profiles memoize per-cap, so repeated breakpoints at the
        same capacity cost dict lookups only.  Static clusters never move
        their capacity, so this is never called on the parity surface."""
        if cluster_cap == self._cap:
            return
        self._cap = cluster_cap
        for i, p in enumerate(self._profiles):
            self._demands[i] = p.demand_at_cap(cluster_cap)

    def remove(self, job_id: int) -> None:
        i = self._pos.pop(job_id)
        last = self._n - 1
        if i != last:
            self._profiles[i] = self._profiles[last]
            for name in self._ARRAYS:
                arr = getattr(self, name)
                arr[i] = arr[last]
            self._pos[int(self._ids[i])] = i
        self._profiles.pop()
        self._n = last

    def ordered(
        self,
        kind: str,
        cluster: ClusterState,
        gpu_floor: Optional[int] = None,
    ) -> List[JobProfile]:
        n = self._n
        if n == 0:
            return []
        submits = self._submits[:n]
        ids = self._ids[:n]
        sel: Optional[np.ndarray] = None
        if gpu_floor is not None:
            sel = np.flatnonzero(self._min_gpus[:n] <= gpu_floor)
            if sel.size == 0:
                return []
        if kind == "priority":
            # Normalization maxima run over the FULL pending queue (Eqs.
            # 9–10) — the floor mask only limits which jobs are *visited*,
            # never what they normalize against.
            scores = _score_vector(
                self._singles[:n],
                self._demands[:n],
                cluster.congestion_alpha(),
            )
            if sel is None:
                perm = rank_order(scores, submits, ids)
            else:
                perm = sel[rank_order(scores[sel], submits[sel], ids[sel])]
        else:  # fcfs: (submit, id)
            if sel is None:
                perm = np.lexsort((ids, submits))
            else:
                perm = sel[np.lexsort((ids[sel], submits[sel]))]
        profiles = self._profiles
        return [profiles[i] for i in perm]


# ------------------------------------------------------------------ simulator
#: Event kinds, in same-timestamp heap order.  All events sharing a timestamp
#: are drained *atomically* — completions release resources, environment
#: updates rescale capacities/prices, arrivals join the queue — before the
#: preemption check and the single scheduling pass for that timestamp run.
#: The end state of a drain is therefore independent of intra-timestamp
#: ordering (updates are absolute, releases/additions commute); the numeric
#: kind order (arrival < completion < env-change, then insertion seq) only
#: fixes the *event log* order, making traces reproducible byte-for-byte.
_ARRIVAL, _COMPLETION, _ENV_CHANGE = 0, 1, 2

ENGINES = ("vectorized", "legacy")


@dataclasses.dataclass
class _RunningJob:
    """Live segment bookkeeping: placement + its record + the generation
    guarding stale completion events + the piecewise accounting ledger
    (cost sub-intervals, live $/s rate, restore window, progress floor)."""

    placement: Placement
    record: JobRecord
    gen: int
    acct: SegmentLedger


class Simulator:
    """Discrete-event simulation of a policy over a job set.

    ``engine="vectorized"`` (default) runs the incremental array-backed
    scheduling path; ``engine="legacy"`` runs the preserved seed path.  Both
    yield identical results on static scenarios (see module docstring).

    ``decision_backend`` selects the kernel implementation for the batched
    placement-decision path (``"numpy"`` default, or ``"jax"`` for the
    jitted kernels in ``core/kernels_decide``; degrades to numpy with a
    warning when jax is missing).  Decisions are bit-identical across
    backends — the seam changes only how fast they are computed.  The legacy
    engine predates the kernels and rejects ``"jax"``.

    ``trace`` switches on the dynamic environment: piecewise-constant
    bandwidth/price multipliers applied as ``_ENV_CHANGE`` events.  When a
    bandwidth drop leaves a link carrying more reserved bandwidth than its
    new capacity (Eq. 6 violation), running jobs on that link are preempted
    latest-started-first until the link fits again: each victim checkpoints
    (progress floors to whole finished iterations), releases its GPUs and
    bandwidth, pays ``restart_penalty_s`` of extra execution on its next
    placement, and re-enters the pending queue at its original submit time.
    A *spot reclaim* (``EnvUpdate.spot`` shrinking a typed spot pool below
    its in-use count — the GPU-side Eq. 5 violation) resolves through the
    identical preempt/settle path, walking ``oversubscribed_pools()`` in
    sorted order.  Dynamic scenarios are vectorized-engine-only; the legacy
    reference predates the event types and refuses them.

    Price breakpoints reprice every affected running segment's ledger
    (piecewise accounting, ``core/accounting.py``) and — when
    ``voluntary_migration_threshold`` is set — trigger the voluntary pass:
    each running job (ascending job id) is offered its best live-priced
    alternative placement (the engine's own ``place`` on a probe where the
    job's resources are released); if staying costs more than
    ``(1 + threshold) ×`` the alternative's remaining cost (restart penalty
    included), the job checkpoints and re-queues exactly like a forced
    victim, logged as ``"migrate"`` and counted in
    ``voluntary_migrations``.  ``None`` (default) disables the pass.
    """

    def __init__(
        self,
        cluster: ClusterState,
        profiles: Sequence[JobProfile],
        policy: SchedulingPolicy,
        *,
        engine: str = "vectorized",
        trace: Optional[BandwidthTrace] = None,
        restart_penalty_s: float = DEFAULT_RESTART_PENALTY_S,
        voluntary_migration_threshold: Optional[float] = None,
        decision_backend: str = DEFAULT_DECISION_BACKEND,
        recorder: Optional["TraceRecorder"] = None,
    ) -> None:
        if engine not in ENGINES:
            raise ValueError(f"unknown engine {engine!r} (have: {ENGINES})")
        if recorder is not None and engine == "legacy":
            raise ValueError(
                'decision tracing requires engine="vectorized"; the legacy '
                "seed engine predates the recorder seam"
            )
        if trace is not None and len(trace) > 0 and engine == "legacy":
            raise ValueError(
                "dynamic scenarios (bandwidth/price traces) require "
                'engine="vectorized"; the legacy seed engine only models '
                "a static environment"
            )
        if decision_backend not in DECISION_BACKENDS:
            raise ValueError(
                f"unknown decision backend {decision_backend!r} "
                f"(have: {DECISION_BACKENDS})"
            )
        if engine == "legacy" and decision_backend != "numpy":
            raise ValueError(
                'engine="legacy" is the seed reference path and does not '
                "route through the decision kernels; it only accepts "
                'decision_backend="numpy"'
            )
        if restart_penalty_s < 0.0:
            raise ValueError("restart_penalty_s must be >= 0")
        if (
            voluntary_migration_threshold is not None
            and voluntary_migration_threshold < 0.0
        ):
            raise ValueError("voluntary_migration_threshold must be >= 0")
        self.cluster = cluster.snapshot()
        self.profiles = {p.spec.job_id: p for p in profiles}
        self.policy = policy
        self.engine = engine
        self.trace = trace
        self.restart_penalty_s = restart_penalty_s
        self.voluntary_migration_threshold = voluntary_migration_threshold
        # Degrades to "numpy" (with a one-time warning) when jax is absent;
        # stamped onto the policy so Pathfinder-based ``place()`` calls (the
        # engine's and the voluntary-migration probes alike) route through
        # the selected kernels.
        self.decision_backend = resolve_backend(decision_backend)
        policy.decision_backend = self.decision_backend
        # Out-of-band decision tracing: stamped onto the policy (like the
        # backend) so Pathfinder-based ``place()`` calls emit per-candidate
        # records.  ``None`` keeps every traced branch dead — the recorder
        # never mutates engine state, so results are bit-identical either way
        # (pinned by tests/test_obs.py).
        self.recorder = recorder
        policy.trace_recorder = recorder
        #: Fleet size at construction, reported in ``SimulationResult`` for
        #: the utilization summary line (spot churn can move the live total).
        self._cluster_gpus0 = self.cluster.total_gpus()

    def run(self) -> SimulationResult:
        cluster = self.cluster
        policy = self.policy
        rec = self.recorder
        legacy = self.engine == "legacy"
        kind = None if legacy else policy.ordering_kind
        ledger = (
            _PendingLedger(cluster.total_gpus())
            if kind in ("priority", "fcfs")
            else None
        )
        if legacy:
            order = lambda pend, now: policy.legacy_order(  # noqa: E731
                list(pend.values()), cluster, now
            )
            place = policy.legacy_place
        elif ledger is not None:
            # Non-strict policies skip unplaceable jobs anyway, so the exact
            # memory-floor mask (see _PendingLedger.ordered) prunes them
            # before any Python placement probe runs.  Strict-FCFS policies
            # must still *visit* a stuck head job (it blocks the queue), so
            # they order the full queue.
            if policy.strict_fcfs:
                order = lambda pend, now: ledger.ordered(  # noqa: E731
                    kind, cluster
                )
            else:
                order = lambda pend, now: ledger.ordered(  # noqa: E731
                    kind, cluster, gpu_floor=cluster.total_free_gpus()
                )
            place = policy.place
        else:
            order = lambda pend, now: policy.order(  # noqa: E731
                list(pend.values()), cluster, now
            )
            place = policy.place

        pending: Dict[int, JobProfile] = {}
        running: Dict[int, _RunningJob] = {}
        records: List[JobRecord] = []
        costs: Dict[int, float] = {}
        log: List[Tuple[float, str, int]] = []
        migrations: Dict[int, int] = {}
        vol_migrations: Dict[int, int] = {}
        stall: Dict[int, float] = {}
        #: iterations still owed per job (== spec.iterations until preempted)
        remaining: Dict[int, int] = {
            j: p.spec.iterations for j, p in self.profiles.items()
        }
        #: completion-event generation per job; bumped on preemption so the
        #: stale completion queued for the aborted segment is skipped on pop
        gen: Dict[int, int] = {j: 0 for j in self.profiles}
        #: preemption time of jobs currently back in the queue (stall clock)
        preempted_at: Dict[int, float] = {}

        # (t, kind, seq, payload): payload is the job id for arrivals, the
        # (job id, generation) pair for completions, and the trace-update
        # index for env changes.  seq keeps heap comparisons total.
        events: List[Tuple[float, int, int, object]] = []
        seq = 0
        # Seed arrivals in job-id order so same-timestamp arrivals drain (and
        # log) canonically regardless of the caller's profile ordering.
        for job_id in sorted(self.profiles):
            p = self.profiles[job_id]
            heapq.heappush(events, (p.spec.submit_time, _ARRIVAL, seq, job_id))
            seq += 1
        arrivals_left = len(self.profiles)
        if self.trace is not None:
            for i, upd in enumerate(self.trace.updates):
                heapq.heappush(events, (upd.time, _ENV_CHANGE, seq, i))
                seq += 1

        def settle(job_id: int, run: _RunningJob, t: float) -> None:
            """Close the segment's ledger at ``t`` and post the accrued cost
            to the Eq. 4 dict — the sole write path for ``costs``, so per-job
            cost is a sum of non-negative settled segments (a simulator
            invariant the old projection back-out could violate)."""
            seg_cost = run.acct.settle(t)
            if seg_cost < 0.0:
                raise RuntimeError(
                    f"negative settled segment cost for job {job_id}: "
                    f"{seg_cost!r}"
                )
            run.record.cost = seg_cost
            costs[job_id] = costs.get(job_id, 0.0) + seg_cost
            if rec is not None:
                rec.on_settle(t, job_id, seg_cost, run.acct.telemetry())

        def preempt(job_id: int, t: float, *, voluntary: bool = False) -> None:
            run = running.pop(job_id)
            # Progress floors to whole checkpointed iterations (the leading
            # restore window of a restarted segment is not training time);
            # the cost accrued so far settles from the piecewise ledger.
            # Settle *before* touching the cluster ledgers: the progress
            # floor and the settle read only the segment ledger, so the
            # order commutes bit-exactly, and an exception in either leaves
            # the reservations intact instead of released-but-unsettled.
            remaining[job_id] = run.acct.remaining_after_checkpoint(
                t, remaining[job_id]
            )
            settle(job_id, run, t)
            _release_placement(cluster, run.placement)
            cluster.release_bandwidth(run.placement.reserved_bw)
            rec = run.record
            rec.finish = t
            rec.preempted = True
            gen[job_id] += 1
            migrations[job_id] = migrations.get(job_id, 0) + 1
            if voluntary:
                vol_migrations[job_id] = vol_migrations.get(job_id, 0) + 1
            stall.setdefault(job_id, 0.0)
            preempted_at[job_id] = t
            pending[job_id] = self.profiles[job_id]
            if ledger is not None:
                ledger.add(self.profiles[job_id])
            log.append((t, "migrate" if voluntary else "preempt", job_id))
            # NB: ``rec`` is this closure's JobRecord local — reach the
            # recorder through ``self``.
            if self.recorder is not None:
                self.recorder.on_sim_event(
                    t, "migrate" if voluntary else "preempt", job_id
                )
                self.recorder.on_preempt(t, job_id, voluntary)

        now = 0.0
        while events:
            now = events[0][0]
            env_changed = False
            prices_changed = False
            spot_changed = False
            # Drain all events at this timestamp before acting (atomic drain;
            # see the kind-order comment above).  Completions drain before
            # env updates, so a segment finishing exactly at a price
            # breakpoint settles at the pre-breakpoint rate (the breakpoint
            # overlaps it for zero duration).
            while events and events[0][0] <= now + 1e-12:
                t_ev, ev_kind, _, payload = heapq.heappop(events)
                if ev_kind == _ARRIVAL:
                    job_id = payload
                    pending[job_id] = self.profiles[job_id]
                    if ledger is not None:
                        ledger.add(self.profiles[job_id])
                    arrivals_left -= 1
                    log.append((t_ev, "arrival", job_id))
                    if rec is not None:
                        rec.on_sim_event(t_ev, "arrival", job_id)
                elif ev_kind == _COMPLETION:
                    job_id, ev_gen = payload
                    run = running.get(job_id)
                    if run is None or run.gen != ev_gen:
                        continue  # stale: the segment was preempted
                    running.pop(job_id)
                    _release_placement(cluster, run.placement)
                    cluster.release_bandwidth(run.placement.reserved_bw)
                    settle(job_id, run, run.record.finish)
                    log.append((t_ev, "complete", job_id))
                    if rec is not None:
                        rec.on_sim_event(t_ev, "complete", job_id)
                else:  # _ENV_CHANGE
                    upd = self.trace.updates[payload]
                    bw_moved, prices_moved, spot_moved = (
                        cluster.apply_env_update(upd)
                    )
                    if bw_moved:
                        env_changed = True
                    if spot_moved:
                        spot_changed = True
                    if prices_moved:
                        prices_changed = True
                        # Split every affected running segment's ledger at
                        # this breakpoint (piecewise accounting).
                        for jid in sorted(running):
                            running[jid].acct.reprice(
                                t_ev, cluster, upd.prices
                            )
                    log.append((t_ev, "env", payload))
                    if rec is not None:
                        rec.on_sim_event(t_ev, "env", payload)

            # Preemptive migration: resolve Eq. 6 violations a bandwidth drop
            # introduced.  Victim rule (deterministic): walk over-subscribed
            # links in sorted name order; on each, preempt the latest-started
            # job (ties: highest job id) until the link fits — LIFO keeps the
            # oldest pipelines running.
            if env_changed:
                # Links whose over-subscription no running job owns (e.g. a
                # background reservation handed to the ClusterState at
                # construction) cannot be resolved by preemption: skip them
                # instead of spinning.
                unresolvable: set = set()
                while True:
                    over = [
                        l
                        for l in cluster.oversubscribed_links()
                        if l not in unresolvable
                    ]
                    if not over:
                        break
                    link = over[0]
                    users = [
                        j
                        for j, run in running.items()
                        if link in run.placement.reserved_bw
                    ]
                    if not users:
                        unresolvable.add(link)
                        continue
                    victim = max(
                        users, key=lambda j: (running[j].record.start, j)
                    )
                    preempt(victim, now)

            # A spot reclaim (or restore) moves the fleet size the Eq. 10
            # priority demands were normalized against; re-anchor the pending
            # ledger before anything re-ranks.
            if spot_changed and ledger is not None:
                ledger.set_cap(cluster.total_gpus())

            # Spot reclaim: a capacity drop that leaves a (region, type) pool
            # holding more in-use GPUs than it now has is the GPU-side Eq. 5
            # violation; resolve it exactly like an over-subscribed link —
            # walk over-subscribed pools in sorted order, preempt the
            # latest-started job using each (ties: highest id) until the pool
            # fits.  Victims route through the same preempt() → SegmentLedger
            # settle path as bandwidth evictions.
            if spot_changed:
                unresolvable_pools: set = set()
                while True:
                    over = [
                        p
                        for p in cluster.oversubscribed_pools()
                        if p not in unresolvable_pools
                    ]
                    if not over:
                        break
                    region, gtype = over[0]
                    users = [
                        j
                        for j, run in running.items()
                        if run.placement.typed_alloc.get(region, {}).get(
                            gtype, 0
                        )
                        > 0
                    ]
                    if not users:
                        # A pool whose deficit no running job owns (e.g. a
                        # hand-built used count) cannot be resolved by
                        # preemption: skip it instead of spinning.
                        unresolvable_pools.add((region, gtype))
                        continue
                    victim = max(
                        users, key=lambda j: (running[j].record.start, j)
                    )
                    preempt(victim, now)

            # Price-aware voluntary migration: after a price breakpoint (and
            # after any forced evictions above), each still-running job is
            # offered its best live-priced alternative.  The probe releases
            # the job's own resources, runs the engine's placement path
            # (Pathfinder + allocator at live prices for BACE-Pipe), and
            # restores the reservation; the job only actually checkpoints
            # when staying costs more than (1 + threshold) × moving —
            # remaining work re-floored to whole checkpointed iterations,
            # restart penalty included — so the restart cost naturally damps
            # flapping.  Jobs are visited in ascending id for determinism;
            # earlier migrations free resources later probes can see.
            threshold = self.voluntary_migration_threshold
            if prices_changed and threshold is not None:
                for job_id in sorted(running):
                    run = running[job_id]
                    time_left = run.record.finish - now
                    if time_left <= 0.0:
                        continue
                    stay_cost = time_left * run.acct.rate
                    prof = self.profiles[job_id]
                    rem = run.acct.remaining_after_checkpoint(
                        now, remaining[job_id]
                    )
                    _release_placement(cluster, run.placement)
                    cluster.release_bandwidth(run.placement.reserved_bw)
                    try:
                        if rec is not None:
                            rec.on_place_begin(now, job_id, probe=True)
                        alt = place(prof, cluster)
                        usable = (
                            alt is not None and alt.total_gpus >= prof.min_gpus
                        )
                        if rec is not None:
                            rec.on_place_end(
                                now,
                                job_id,
                                alt if usable else None,
                                self.decision_backend,
                                probe=True,
                            )
                        move_cost = None
                        if usable:
                            e_alt = (
                                rem * iteration_time(prof, alt)
                                + self.restart_penalty_s
                            )
                            move_cost = e_alt * placement_power_rate(
                                prof, alt, cluster
                            )
                    finally:
                        # The probe's transient release must not leak: an
                        # exception anywhere in the pricing path restores
                        # the job's reservation before propagating.
                        _reserve_placement(cluster, run.placement)
                        cluster.reserve_bandwidth(run.placement.reserved_bw)
                    moving = (
                        move_cost is not None
                        and stay_cost > (1.0 + threshold) * move_cost
                    )
                    if rec is not None:
                        rec.on_migration_probe(
                            now, job_id, stay_cost, move_cost, moving
                        )
                    if moving:
                        preempt(job_id, now, voluntary=True)

            if not pending and not running and arrivals_left == 0:
                if rec is not None:
                    rec.on_timestamp(now, cluster, 0, running)
                break  # only trailing env events remain; nothing can change

            # Scheduling pass (work-conserving).
            progressed = True
            while progressed and pending:
                progressed = False
                queue = order(pending, now)
                if rec is not None:
                    queue = list(queue)
                    rec.on_queue_order(now, queue, cluster)
                for prof in queue:
                    if rec is not None:
                        rec.on_place_begin(now, prof.spec.job_id)
                    placement = place(prof, cluster)
                    if rec is not None:
                        ok = (
                            placement is not None
                            and placement.total_gpus >= prof.min_gpus
                        )
                        rec.on_place_end(
                            now,
                            prof.spec.job_id,
                            placement if ok else None,
                            self.decision_backend,
                        )
                    if placement is None or placement.total_gpus < prof.min_gpus:
                        if policy.strict_fcfs:
                            break  # HoL: the stuck head job blocks the queue
                        continue
                    job_id = prof.spec.job_id
                    _reserve_placement(cluster, placement)
                    cluster.reserve_bandwidth(placement.reserved_bw)
                    t_it = iteration_time(prof, placement)
                    e = remaining[job_id] * t_it  # Eq. (2), remaining work
                    restore = 0.0
                    if job_id in preempted_at:
                        stall[job_id] += now - preempted_at.pop(job_id)
                        restore = self.restart_penalty_s
                        e += restore
                    finish = now + e
                    record = JobRecord(
                        job_id=job_id,
                        model_name=prof.spec.model.name,
                        submit=prof.spec.submit_time,
                        start=now,
                        finish=finish,
                        placement=placement,
                        iteration_seconds=t_it,
                    )
                    records.append(record)
                    # Cost is *not* charged here: the segment's ledger
                    # accrues piecewise and settles on completion/preemption.
                    running[job_id] = _RunningJob(
                        placement=placement,
                        record=record,
                        gen=gen[job_id],
                        acct=SegmentLedger.open(
                            prof,
                            placement,
                            cluster,
                            start=now,
                            restore_s=restore,
                            iteration_seconds=t_it,
                            execution_seconds=e,
                        ),
                    )
                    del pending[job_id]
                    if ledger is not None:
                        ledger.remove(job_id)
                    heapq.heappush(
                        events,
                        (finish, _COMPLETION, seq, (job_id, gen[job_id])),
                    )
                    seq += 1
                    log.append((now, "start", job_id))
                    if rec is not None:
                        rec.on_sim_event(now, "start", job_id)
                        rec.on_start(
                            now,
                            job_id,
                            placement,
                            running[job_id].acct.rate,
                            t_it,
                            finish,
                            restore,
                        )
                    progressed = True
                    break  # re-rank: alpha/normalization changed

            if pending and not running and not events:
                stuck = sorted(pending)
                raise RuntimeError(
                    f"deadlock: jobs {stuck} unplaceable on an idle cluster "
                    f"(policy={policy.name})"
                )

            # Telemetry gauges sample once per drained timestamp, after the
            # scheduling pass (so queue depth / occupancy reflect this
            # instant's final state).
            if rec is not None:
                rec.on_timestamp(now, cluster, len(pending), running)

        return SimulationResult(
            policy=policy.name,
            records=sorted(records, key=lambda r: (r.job_id, r.start)),
            costs=costs,
            makespan=max((r.finish for r in records), default=0.0),
            migrations=migrations,
            stall_seconds=stall,
            voluntary_migrations=vol_migrations,
            events=log,
            cluster_gpus=self._cluster_gpus0,
        )


def simulate(
    cluster: ClusterState,
    profiles: Sequence[JobProfile],
    policy: SchedulingPolicy,
    *,
    engine: str = "vectorized",
    trace: Optional[BandwidthTrace] = None,
    restart_penalty_s: float = DEFAULT_RESTART_PENALTY_S,
    voluntary_migration_threshold: Optional[float] = None,
    decision_backend: str = DEFAULT_DECISION_BACKEND,
    recorder: Optional["TraceRecorder"] = None,
) -> SimulationResult:
    return Simulator(
        cluster,
        profiles,
        policy,
        engine=engine,
        trace=trace,
        restart_penalty_s=restart_penalty_s,
        voluntary_migration_threshold=voluntary_migration_threshold,
        decision_backend=decision_backend,
        recorder=recorder,
    ).run()
