"""Event-driven multi-job simulator + the BACE-Pipe scheduling policy.

The simulator advances a global clock through job arrivals and completions.
At every decision point the active policy (BACE-Pipe, a baseline, or an
ablation) orders the pending queue and attempts placements; placed jobs
reserve GPUs (Eq. 5) and link bandwidth (Eq. 6) until completion.  All
policies are work-conserving: a job that cannot be placed is skipped, not a
barrier — HoL blocking in this model is *resource* occupancy, exactly the
phenomenon the paper analyses.

Two engines share the identical event loop (see DESIGN.md):

* ``vectorized`` (default) — pending-queue invariants (``E_j(1)``, ``b_j`` at
  ``K*``, submit keys) live in aligned arrays inside ``_PendingLedger``; a
  successful placement triggers an incremental re-rank (only ``alpha`` and
  the two normalization maxima change, an O(n) recombine + O(n log n)
  ``lexsort``) instead of the seed's recompute-everything re-order.
* ``legacy`` — the seed engine preserved verbatim (``legacy.py``): full
  policy re-order with per-call invariant recomputation.  Kept as the parity
  reference and the benchmark baseline.

Both engines produce bit-identical ``SimulationResult``s; the engine-parity
test enforces this for every policy and ablation.
"""

from __future__ import annotations

import abc
import dataclasses
import heapq
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .allocator import cost_min_allocate
from .cluster import ClusterState
from .job import JobProfile
from .legacy import legacy_find_placement, legacy_order_by_priority
from .pathfinder import find_placement
from .placement import Placement
from .priority import _score_vector, order_by_priority, rank_order
from .timing import electricity_cost, iteration_time


class SchedulingPolicy(abc.ABC):
    """Order + place: the two decisions every scheduler makes.

    ``strict_fcfs``: classic FIFO semantics — when the job at the head of the
    (policy-ordered) queue cannot be placed, the scheduling pass stops; jobs
    behind it wait.  This is how the paper's FCFS baselines exhibit HoL
    blocking.  BACE-Pipe instead *re-orders* the queue every event (Eq. 12),
    which subsumes skipping a stuck job.

    ``ordering_kind`` declares the ordering rule ("priority" for Eq. 12,
    "fcfs" for submit-time order, None for anything else) so the vectorized
    engine can maintain the rank incrementally; policies with ``None`` fall
    back to ``order()`` every pass.
    """

    name: str = "base"
    strict_fcfs: bool = False
    ordering_kind: Optional[str] = None

    @abc.abstractmethod
    def order(
        self, pending: Sequence[JobProfile], cluster: ClusterState, now: float
    ) -> List[JobProfile]:
        ...

    @abc.abstractmethod
    def place(
        self, profile: JobProfile, cluster: ClusterState
    ) -> Optional[Placement]:
        ...

    # Seed-engine hooks: the legacy engine routes through these so the
    # reference path keeps the seed's exact implementations (and costs).
    def legacy_order(
        self, pending: Sequence[JobProfile], cluster: ClusterState, now: float
    ) -> List[JobProfile]:
        return self.order(pending, cluster, now)

    def legacy_place(
        self, profile: JobProfile, cluster: ClusterState
    ) -> Optional[Placement]:
        return self.place(profile, cluster)


def fcfs_order(
    pending: Sequence[JobProfile], cluster: ClusterState, now: float
) -> List[JobProfile]:
    return sorted(pending, key=lambda p: (p.spec.submit_time, p.spec.job_id))


class BACEPipePolicy(SchedulingPolicy):
    """The paper's scheduler: dynamic priority -> Pathfinder -> Cost-Min."""

    name = "bace-pipe"

    def __init__(self, *, use_priority: bool = True) -> None:
        self.use_priority = use_priority
        self.ordering_kind = "priority" if use_priority else "fcfs"

    def order(self, pending, cluster, now):
        if self.use_priority:
            return order_by_priority(pending, cluster)
        return fcfs_order(pending, cluster, now)

    def place(self, profile, cluster):
        return find_placement(profile, cluster, allocator=cost_min_allocate)

    def legacy_order(self, pending, cluster, now):
        if self.use_priority:
            return legacy_order_by_priority(pending, cluster)
        return fcfs_order(pending, cluster, now)

    def legacy_place(self, profile, cluster):
        return legacy_find_placement(profile, cluster, allocator=cost_min_allocate)


# --------------------------------------------------------------------- result
@dataclasses.dataclass
class JobRecord:
    job_id: int
    model_name: str
    submit: float
    start: float
    finish: float
    placement: Placement
    iteration_seconds: float

    @property
    def wait(self) -> float:  # W_j
        return self.start - self.submit

    @property
    def execution(self) -> float:  # E_j
        return self.finish - self.start

    @property
    def jct(self) -> float:  # T_j = W_j + E_j
        return self.finish - self.submit


@dataclasses.dataclass
class SimulationResult:
    policy: str
    records: List[JobRecord]
    costs: Dict[int, float]
    makespan: float

    @property
    def average_jct(self) -> float:
        return sum(r.jct for r in self.records) / len(self.records)

    @property
    def total_cost(self) -> float:
        return sum(self.costs.values())

    def summary(self) -> str:
        return (
            f"{self.policy}: avg_jct={self.average_jct / 3600.0:.3f} h, "
            f"total_cost=${self.total_cost:.2f}, "
            f"makespan={self.makespan / 3600.0:.3f} h"
        )


# --------------------------------------------------------------- pending set
class _PendingLedger:
    """Pending queue with its scheduling invariants held in aligned arrays.

    Per-job quantities that never change while a job waits — ``E_j(1)``,
    ``b_j`` at ``K*(cluster)``, submit time, id — are gathered once on
    arrival (O(1) amortized; the profile memoizes the math).  A re-rank after
    a placement therefore only recombines the arrays under the new ``alpha``
    and normalization maxima: O(n) numpy arithmetic + one O(n log n) lexsort,
    versus the seed's O(n · K) invariant recomputation per pass.  Removal is
    a swap-pop, keeping the arrays dense.
    """

    def __init__(self, cluster_cap: int) -> None:
        self._cap = cluster_cap
        self._profiles: List[JobProfile] = []
        self._singles: List[float] = []
        self._demands: List[float] = []
        self._submits: List[float] = []
        self._ids: List[int] = []
        self._pos: Dict[int, int] = {}

    def __len__(self) -> int:
        return len(self._profiles)

    def add(self, profile: JobProfile) -> None:
        job_id = profile.spec.job_id
        self._pos[job_id] = len(self._profiles)
        self._profiles.append(profile)
        self._singles.append(profile.single_gpu_execution())
        self._demands.append(profile.demand_at_cap(self._cap))
        self._submits.append(profile.spec.submit_time)
        self._ids.append(job_id)

    def remove(self, job_id: int) -> None:
        i = self._pos.pop(job_id)
        last = len(self._profiles) - 1
        if i != last:
            for arr in (
                self._profiles,
                self._singles,
                self._demands,
                self._submits,
                self._ids,
            ):
                arr[i] = arr[last]
            self._pos[self._ids[i]] = i
        for arr in (
            self._profiles,
            self._singles,
            self._demands,
            self._submits,
            self._ids,
        ):
            arr.pop()

    def ordered(self, kind: str, cluster: ClusterState) -> List[JobProfile]:
        n = len(self._profiles)
        if n <= 1:
            return list(self._profiles)
        submits = np.array(self._submits)
        ids = np.array(self._ids, dtype=np.int64)
        if kind == "priority":
            scores = _score_vector(
                np.array(self._singles),
                np.array(self._demands),
                cluster.congestion_alpha(),
            )
            perm = rank_order(scores, submits, ids)
        else:  # fcfs: (submit, id)
            perm = np.lexsort((ids, submits))
        profiles = self._profiles
        return [profiles[i] for i in perm]


# ------------------------------------------------------------------ simulator
_ARRIVAL, _COMPLETION = 0, 1

ENGINES = ("vectorized", "legacy")


class Simulator:
    """Discrete-event simulation of a policy over a job set.

    ``engine="vectorized"`` (default) runs the incremental array-backed
    scheduling path; ``engine="legacy"`` runs the preserved seed path.  Both
    yield identical results (see module docstring).
    """

    def __init__(
        self,
        cluster: ClusterState,
        profiles: Sequence[JobProfile],
        policy: SchedulingPolicy,
        *,
        engine: str = "vectorized",
    ) -> None:
        if engine not in ENGINES:
            raise ValueError(f"unknown engine {engine!r} (have: {ENGINES})")
        self.cluster = cluster.snapshot()
        self.profiles = {p.spec.job_id: p for p in profiles}
        self.policy = policy
        self.engine = engine

    def run(self) -> SimulationResult:
        cluster = self.cluster
        policy = self.policy
        legacy = self.engine == "legacy"
        kind = None if legacy else policy.ordering_kind
        ledger = (
            _PendingLedger(cluster.total_gpus())
            if kind in ("priority", "fcfs")
            else None
        )
        if legacy:
            order = lambda pend, now: policy.legacy_order(  # noqa: E731
                list(pend.values()), cluster, now
            )
            place = policy.legacy_place
        elif ledger is not None:
            order = lambda pend, now: ledger.ordered(kind, cluster)  # noqa: E731
            place = policy.place
        else:
            order = lambda pend, now: policy.order(  # noqa: E731
                list(pend.values()), cluster, now
            )
            place = policy.place

        pending: Dict[int, JobProfile] = {}
        running: Dict[int, Tuple[Placement, float]] = {}
        records: List[JobRecord] = []
        costs: Dict[int, float] = {}
        events: List[Tuple[float, int, int, int]] = []  # (t, kind, seq, job)
        seq = 0
        for p in self.profiles.values():
            heapq.heappush(events, (p.spec.submit_time, _ARRIVAL, seq, p.spec.job_id))
            seq += 1

        now = 0.0
        while events:
            now = events[0][0]
            # Drain all events at this timestamp before scheduling.
            while events and events[0][0] <= now + 1e-12:
                _, ev_kind, _, job_id = heapq.heappop(events)
                if ev_kind == _ARRIVAL:
                    pending[job_id] = self.profiles[job_id]
                    if ledger is not None:
                        ledger.add(self.profiles[job_id])
                else:  # completion
                    placement, _ = running.pop(job_id)
                    cluster.release_gpus(placement.alloc)
                    cluster.release_bandwidth(placement.reserved_bw)

            # Scheduling pass (work-conserving).
            progressed = True
            while progressed and pending:
                progressed = False
                for prof in order(pending, now):
                    placement = place(prof, cluster)
                    if placement is None or placement.total_gpus < prof.min_gpus:
                        if policy.strict_fcfs:
                            break  # HoL: the stuck head job blocks the queue
                        continue
                    cluster.reserve_gpus(placement.alloc)
                    cluster.reserve_bandwidth(placement.reserved_bw)
                    t_it = iteration_time(prof, placement)
                    e = prof.spec.iterations * t_it  # Eq. (2)
                    finish = now + e
                    running[prof.spec.job_id] = (placement, now)
                    records.append(
                        JobRecord(
                            job_id=prof.spec.job_id,
                            model_name=prof.spec.model.name,
                            submit=prof.spec.submit_time,
                            start=now,
                            finish=finish,
                            placement=placement,
                            iteration_seconds=t_it,
                        )
                    )
                    costs[prof.spec.job_id] = electricity_cost(
                        prof, placement, cluster, execution_seconds=e
                    )
                    del pending[prof.spec.job_id]
                    if ledger is not None:
                        ledger.remove(prof.spec.job_id)
                    heapq.heappush(
                        events, (finish, _COMPLETION, seq, prof.spec.job_id)
                    )
                    seq += 1
                    progressed = True
                    break  # re-rank: alpha/normalization changed

            if pending and not running and not events:
                stuck = sorted(pending)
                raise RuntimeError(
                    f"deadlock: jobs {stuck} unplaceable on an idle cluster "
                    f"(policy={policy.name})"
                )

        return SimulationResult(
            policy=policy.name,
            records=sorted(records, key=lambda r: r.job_id),
            costs=costs,
            makespan=now,
        )


def simulate(
    cluster: ClusterState,
    profiles: Sequence[JobProfile],
    policy: SchedulingPolicy,
    *,
    engine: str = "vectorized",
) -> SimulationResult:
    return Simulator(cluster, profiles, policy, engine=engine).run()
