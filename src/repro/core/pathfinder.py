"""Bandwidth-Aware Multi-Region Pathfinder — paper Alg. 1.

Phase 1: if any single region can host all ``K*`` GPUs, take the cheapest
such region (JCT- and cost-optimal: zero WAN traffic).

Phase 2: otherwise grow a path from every seed region, Prim-style, always
following the highest-bandwidth outgoing link to an unvisited region with
free GPUs, admitting an edge only while the would-be communication time
``A / b_tmp`` stays within the compute time ``t_comp(g')`` (the inequality
that keeps communication off the pipeline's critical path).  Each candidate
path is priced by the Cost-Min Allocator; the path aggregating the most GPUs
wins, ties broken by mean electricity price.

Phase 2 runs as one *batched* frontier (``core/kernels_decide``): every seed
region advances one hop per step via masked argmax on the residual R×R
bandwidth matrix, on either the numpy or the jitted jax backend — the
per-seed walks are state-independent, so batching them is exact.  Candidate
finalization (Cost-Min pricing, ``build_placement``, ``average_price``) stays
on the scalar path per surviving seed: those sums iterate dicts, and
re-associating them vectorized could flip a last-ulp price tie-break.  The
O(1) whole-cluster rejection is kept; PR 1's per-seed reachability bound is
superseded by an exact incumbent mask (a walked seed whose aggregated GPU
count falls strictly below the incumbent's cannot win and skips
finalization).  Decisions (including all tie-breaks) are identical to the
reference implementation in ``legacy.py`` on either backend; the
engine-parity and decision-backend suites enforce that.
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Callable, Dict, List, Optional, Tuple

import numpy as np

if TYPE_CHECKING:
    # Typing-only obs seam (reprolint RPL601) — never imported at runtime.
    from repro.obs.protocol import TraceRecorder

from .allocator import cost_min_allocate
from .cluster import ClusterState
from .job import JobProfile
from .kernels_decide import (
    DEFAULT_DECISION_BACKEND,
    decay_table_len,
    phase1_pick,
    prim_expand,
)
from .placement import Placement, build_placement
from .timing import average_price

AllocatorFn = Callable[[ClusterState, List[str], int], Dict[str, int]]


@dataclasses.dataclass(frozen=True)
class PathCandidate:
    path: Tuple[str, ...]
    gpus: int
    avg_price: float
    alloc: Dict[str, int]


def placement_feasible(
    placement: Placement, cluster: ClusterState, *, rel_tol: float = 1e-9
) -> bool:
    """Convenience probe: can every crossing edge of this placement still
    carry the share the job reserved under the current (possibly shrunk)
    link capacities (Eq. 6)?  For callers re-validating a single placement
    (control-plane tooling, examples).  Note the engine's actual preemption
    trigger is the *aggregate* check across jobs sharing a link —
    ``ClusterState.oversubscribed_links`` — which subsumes this per-job
    condition.

    The tolerance is purely *relative*: an absolute epsilon on top of it
    would let any sub-epsilon overage pass on a low-capacity link and any
    tiny reservation pass on a zero-capacity one — masking genuine Eq. 6
    violations exactly where links are thinnest."""
    for (u, v), share in placement.reserved_bw.items():
        cap = cluster.link_bandwidth(u, v)
        if share > cap * (1.0 + rel_tol):
            return False
    return True


def find_placement(
    profile: JobProfile,
    cluster: ClusterState,
    *,
    k_star: Optional[int] = None,
    allocator: AllocatorFn = cost_min_allocate,
    backend: str = DEFAULT_DECISION_BACKEND,
    recorder: Optional["TraceRecorder"] = None,
) -> Optional[Placement]:
    """Alg. 1 end to end.  Returns None when even the best path cannot reach
    the job's memory floor (``min_gpus``) — the job must wait.

    ``backend`` selects the kernel implementation for the batched Phase 2
    frontier (``"numpy"`` or ``"jax"``); decisions are bit-identical either
    way (see module docstring).

    ``recorder`` (the ``repro.obs`` protocol seam) receives one
    ``on_candidate`` record per admission decision: the O(1) whole-cluster
    reject, the Phase 1 pick, and every Phase 2 seed finalization with the
    constraint that bound it — ``"gpu"`` for Eq. 5 capacity/floor failures,
    ``"bandwidth"`` for Eq. 6 comm-over-comp rejections.  Purely
    observational; decisions are identical with or without it."""
    job_id = profile.spec.job_id
    k = k_star if k_star is not None else profile.optimal_gpus(cluster.total_gpus())
    k = max(k, profile.min_gpus)

    # O(1) reject: any path aggregates at most the cluster-wide free total,
    # so below the memory floor no placement exists (the reference path walks
    # every seed to conclude the same).
    free_total = cluster.total_free_gpus()
    if free_total < profile.min_gpus:
        if recorder is not None:
            recorder.on_candidate(
                job_id, "reject", (), free_total, "rejected", "gpu"
            )
        return None

    free = cluster.free_vector()
    names = cluster.region_names()
    name_rank = cluster.name_rank_vector()

    hetero = cluster.is_heterogeneous

    # ---------------------------------------------- Phase 1: single region
    single = phase1_pick(free, cluster.price_vector(), name_rank, k)
    if single >= 0:
        best = names[single]
        if not hetero:
            placement = build_placement(
                profile, cluster, [best], {best: k}, require_comm_fits_comp=True
            )
            if recorder is not None:
                recorder.on_candidate(
                    job_id,
                    "phase1",
                    (best,),
                    k,
                    "chosen",
                    None,
                    average_price(placement, cluster),
                )
            return placement
        # Heterogeneous: the cheapest region's granted types may sit below
        # the job's memory floor (build_placement validates against the
        # grant); fall through to Phase 2 rather than failing the job.
        try:
            placement = build_placement(
                profile, cluster, [best], {best: k}, require_comm_fits_comp=True
            )
        except ValueError:
            if recorder is not None:
                recorder.on_candidate(
                    job_id, "phase1", (best,), k, "floor-failed", "gpu"
                )
        else:
            if recorder is not None:
                recorder.on_candidate(
                    job_id,
                    "phase1",
                    (best,),
                    k,
                    "chosen",
                    None,
                    average_price(placement, cluster),
                )
            return placement

    # ------------------------------------------ Phase 2: batched expansion
    act = profile.spec.model.activation_bytes
    avail = cluster.available_matrix()
    n_regions = len(names)
    # Admission heuristic on heterogeneous clusters: evaluate t_comp at the
    # most conservative (slowest) FLOPS a region along the path could grant —
    # slower stages tolerate slower links.  The final build_placement gate
    # re-checks against the actual typed grant.  Homogeneous clusters pass a
    # constant reference vector, whose running min is the reference FLOPS —
    # the kernel's one t_comp formula covers both cases bit-exactly.
    if hetero:
        flops_vec = cluster.min_available_flops_vector(profile.gpu_flops)
    else:
        flops_vec = np.full(n_regions, profile.gpu_flops)

    # Free-region compaction: seeds and every admissible hop of the Prim
    # walk require free GPUs (the kernels' candidate mask is
    # ``has_free & ...``), so the whole Phase 2 frontier lives in the
    # free-region subgraph.  On a saturated cluster F << R and the kernels'
    # O(R²)-per-step cost collapses to O(F²) without changing a single
    # decision: the submatrix preserves bandwidth values, relative name
    # ranks, and seed order (ascending region index), and the skipped seeds
    # all have path_len == 0.  The compacted side is padded up to a bucket
    # of 32 (capped at R) so the jax backend sees a bounded set of shapes;
    # pad lanes have no free GPUs and no bandwidth, so they never activate.
    free_idx = np.flatnonzero(free > 0)
    n_sub = free_idx.size
    if n_sub < n_regions:
        pad = min(n_regions, ((n_sub + 31) // 32) * 32)
        avail_c = np.zeros((pad, pad))
        avail_c[:n_sub, :n_sub] = avail[np.ix_(free_idx, free_idx)]
        free_c = np.zeros(pad, dtype=free.dtype)
        free_c[:n_sub] = free[free_idx]
        rank_c = np.full(pad, -1, dtype=name_rank.dtype)
        rank_c[:n_sub] = name_rank[free_idx]
        flops_c = np.ones(pad)
        flops_c[:n_sub] = flops_vec[free_idx]
    else:
        avail_c, free_c, rank_c, flops_c = avail, free, name_rank, flops_vec

    g_arr, len_arr, paths = prim_expand(
        avail_c,
        free_c,
        rank_c,
        flops_c,
        profile.decay_table(decay_table_len(k)),
        profile.fwd_flops_per_microbatch,
        profile.stage_overhead,
        act,
        k,
        backend=backend,
    )
    if n_sub < n_regions:
        seed_regions = free_idx
    else:
        seed_regions = np.arange(n_regions)

    # Scalar finalization in seed order (first-seed-wins on exact ties, as
    # in the reference).  The incumbent mask is exact: a seed whose walk
    # aggregated strictly fewer GPUs than the incumbent cannot win.
    best_cand: Optional[PathCandidate] = None
    for si in range(seed_regions.size):
        g = int(g_arr[si])
        path_len = int(len_arr[si])
        if g < profile.min_gpus or g < path_len or path_len == 0:
            if recorder is not None and path_len > 0:
                seed_path = tuple(
                    names[int(seed_regions[int(paths[si, j])])]
                    for j in range(path_len)
                )
                recorder.on_candidate(
                    job_id, "phase2", seed_path, g, "skipped-floor", "gpu"
                )
            continue
        if best_cand is not None and g < best_cand.gpus:
            if recorder is not None:
                seed_path = tuple(
                    names[int(seed_regions[int(paths[si, j])])]
                    for j in range(path_len)
                )
                recorder.on_candidate(
                    job_id, "phase2", seed_path, g, "dominated", None
                )
            continue
        path = [names[int(seed_regions[int(paths[si, j])])]
                for j in range(path_len)]
        try:
            if recorder is not None and getattr(
                allocator, "traceable", False
            ):
                alloc = allocator(cluster, path, g, recorder=recorder)
            else:
                alloc = allocator(cluster, path, g)
        except ValueError:
            if recorder is not None:
                recorder.on_candidate(
                    job_id, "phase2", tuple(path), g, "alloc-failed", "gpu"
                )
            continue
        try:
            placement = build_placement(
                profile, cluster, path, alloc, require_comm_fits_comp=True
            )
        except ValueError:
            if recorder is not None:
                recorder.on_candidate(
                    job_id,
                    "phase2",
                    tuple(path),
                    g,
                    "comm-infeasible",
                    "bandwidth",
                )
            continue
        cand = PathCandidate(
            path=tuple(path),
            gpus=g,
            avg_price=average_price(placement, cluster),
            alloc=alloc,
        )
        if recorder is not None:
            recorder.on_candidate(
                job_id,
                "phase2",
                cand.path,
                cand.gpus,
                "candidate",
                None,
                cand.avg_price,
            )
        if (
            best_cand is None
            or cand.gpus > best_cand.gpus
            or (cand.gpus == best_cand.gpus and cand.avg_price < best_cand.avg_price)
        ):
            best_cand = cand

    if best_cand is None:
        return None
    if recorder is not None:
        recorder.on_candidate(
            job_id,
            "phase2",
            best_cand.path,
            best_cand.gpus,
            "chosen",
            None,
            best_cand.avg_price,
        )
    return build_placement(
        profile,
        cluster,
        list(best_cand.path),
        best_cand.alloc,
        require_comm_fits_comp=True,
    )
