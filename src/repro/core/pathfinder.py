"""Bandwidth-Aware Multi-Region Pathfinder — paper Alg. 1.

Phase 1: if any single region can host all ``K*`` GPUs, take the cheapest
such region (JCT- and cost-optimal: zero WAN traffic).

Phase 2: otherwise grow a path from every seed region, Prim-style, always
following the highest-bandwidth outgoing link to an unvisited region with
free GPUs, admitting an edge only while the would-be communication time
``A / b_tmp`` stays within the compute time ``t_comp(g')`` (the inequality
that keeps communication off the pipeline's critical path).  Each candidate
path is priced by the Cost-Min Allocator; the path aggregating the most GPUs
wins, ties broken by mean electricity price.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

from .allocator import cost_min_allocate
from .cluster import ClusterState
from .job import JobProfile
from .placement import Placement, build_placement
from .timing import average_price

AllocatorFn = Callable[[ClusterState, List[str], int], Dict[str, int]]


@dataclasses.dataclass(frozen=True)
class PathCandidate:
    path: Tuple[str, ...]
    gpus: int
    avg_price: float
    alloc: Dict[str, int]


def find_placement(
    profile: JobProfile,
    cluster: ClusterState,
    *,
    k_star: Optional[int] = None,
    allocator: AllocatorFn = cost_min_allocate,
) -> Optional[Placement]:
    """Alg. 1 end to end.  Returns None when even the best path cannot reach
    the job's memory floor (``min_gpus``) — the job must wait."""
    k = k_star if k_star is not None else profile.optimal_gpus(cluster.total_gpus())
    k = max(k, profile.min_gpus)

    # ---------------------------------------------- Phase 1: single region
    singles = [r for r, free in cluster.free_gpus.items() if free >= k]
    if singles:
        best = min(singles, key=lambda r: (cluster.price(r), r))
        return build_placement(
            profile, cluster, [best], {best: k}, require_comm_fits_comp=True
        )

    # ------------------------------------------ Phase 2: greedy expansion
    act = profile.spec.model.activation_bytes
    best_cand: Optional[PathCandidate] = None
    for seed in cluster.region_names():
        if cluster.free_gpus[seed] < 1:
            continue
        path: List[str] = [seed]
        tail = seed
        g = min(cluster.free_gpus[seed], k)
        b_min = float("inf")
        while len(path) < len(cluster.regions) and g < k:
            # Highest-bandwidth (residual) outgoing link to a fresh region.
            cands = [
                u
                for u in cluster.region_names()
                if u not in path
                and cluster.free_gpus[u] > 0
                and cluster.available_bandwidth(tail, u) > 0.0
            ]
            if not cands:
                break
            nxt = max(
                cands, key=lambda u: (cluster.available_bandwidth(tail, u), u)
            )
            b_tmp = min(b_min, cluster.available_bandwidth(tail, nxt))
            g_new = min(g + cluster.free_gpus[nxt], k)
            # Alg. 1 line 13: communication must keep up with compute.
            if act / b_tmp > profile.t_comp(g_new):
                break
            path.append(nxt)
            tail = nxt
            b_min, g = b_tmp, g_new

        if g < profile.min_gpus or g < len(path):
            continue
        try:
            alloc = allocator(cluster, path, g)
        except ValueError:
            continue
        try:
            placement = build_placement(
                profile, cluster, path, alloc, require_comm_fits_comp=True
            )
        except ValueError:
            continue
        cand = PathCandidate(
            path=tuple(path),
            gpus=g,
            avg_price=average_price(placement, cluster),
            alloc=alloc,
        )
        if (
            best_cand is None
            or cand.gpus > best_cand.gpus
            or (cand.gpus == best_cand.gpus and cand.avg_price < best_cand.avg_price)
        ):
            best_cand = cand

    if best_cand is None:
        return None
    return build_placement(
        profile,
        cluster,
        list(best_cand.path),
        best_cand.alloc,
        require_comm_fits_comp=True,
    )
