"""Bandwidth-Aware Multi-Region Pathfinder — paper Alg. 1.

Phase 1: if any single region can host all ``K*`` GPUs, take the cheapest
such region (JCT- and cost-optimal: zero WAN traffic).

Phase 2: otherwise grow a path from every seed region, Prim-style, always
following the highest-bandwidth outgoing link to an unvisited region with
free GPUs, admitting an edge only while the would-be communication time
``A / b_tmp`` stays within the compute time ``t_comp(g')`` (the inequality
that keeps communication off the pipeline's critical path).  Each candidate
path is priced by the Cost-Min Allocator; the path aggregating the most GPUs
wins, ties broken by mean electricity price.

This implementation runs over the cluster's dense numpy ledgers: one residual
R×R bandwidth matrix snapshot per call, argmax-based neighbor selection, and
two early exits — an O(1) rejection when the whole cluster cannot reach the
job's memory floor, and a per-seed bound that skips seeds whose reachable
free-GPU total cannot strictly beat the incumbent candidate.  Decisions
(including all tie-breaks) are identical to the reference implementation in
``legacy.py``; the engine-parity test enforces that.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from .allocator import cost_min_allocate
from .cluster import ClusterState
from .job import JobProfile
from .placement import Placement, build_placement
from .timing import average_price

AllocatorFn = Callable[[ClusterState, List[str], int], Dict[str, int]]


@dataclasses.dataclass(frozen=True)
class PathCandidate:
    path: Tuple[str, ...]
    gpus: int
    avg_price: float
    alloc: Dict[str, int]


def placement_feasible(
    placement: Placement, cluster: ClusterState, *, rel_tol: float = 1e-9
) -> bool:
    """Convenience probe: can every crossing edge of this placement still
    carry the share the job reserved under the current (possibly shrunk)
    link capacities (Eq. 6)?  For callers re-validating a single placement
    (control-plane tooling, examples).  Note the engine's actual preemption
    trigger is the *aggregate* check across jobs sharing a link —
    ``ClusterState.oversubscribed_links`` — which subsumes this per-job
    condition.

    The tolerance is purely *relative*: an absolute epsilon on top of it
    would let any sub-epsilon overage pass on a low-capacity link and any
    tiny reservation pass on a zero-capacity one — masking genuine Eq. 6
    violations exactly where links are thinnest."""
    for (u, v), share in placement.reserved_bw.items():
        cap = cluster.link_bandwidth(u, v)
        if share > cap * (1.0 + rel_tol):
            return False
    return True


def find_placement(
    profile: JobProfile,
    cluster: ClusterState,
    *,
    k_star: Optional[int] = None,
    allocator: AllocatorFn = cost_min_allocate,
) -> Optional[Placement]:
    """Alg. 1 end to end.  Returns None when even the best path cannot reach
    the job's memory floor (``min_gpus``) — the job must wait."""
    k = k_star if k_star is not None else profile.optimal_gpus(cluster.total_gpus())
    k = max(k, profile.min_gpus)

    # O(1) reject: any path aggregates at most the cluster-wide free total,
    # so below the memory floor no placement exists (the reference path walks
    # every seed to conclude the same).
    free_total = cluster.total_free_gpus()
    if free_total < profile.min_gpus:
        return None

    free = cluster._free
    names = cluster._names
    name_rank = cluster._name_rank

    hetero = cluster.is_heterogeneous

    # ---------------------------------------------- Phase 1: single region
    single_mask = free >= k
    if single_mask.any():
        idxs = np.flatnonzero(single_mask)
        prices = cluster._price[idxs]
        cheapest = idxs[prices == prices.min()]
        # min by (price, name): among equal-price regions take the smallest name
        best = names[cheapest[np.argmin(name_rank[cheapest])]]
        if not hetero:
            return build_placement(
                profile, cluster, [best], {best: k}, require_comm_fits_comp=True
            )
        # Heterogeneous: the cheapest region's granted types may sit below
        # the job's memory floor (build_placement validates against the
        # grant); fall through to Phase 2 rather than failing the job.
        try:
            return build_placement(
                profile, cluster, [best], {best: k}, require_comm_fits_comp=True
            )
        except ValueError:
            pass

    # ------------------------------------------ Phase 2: greedy expansion
    act = profile.spec.model.activation_bytes
    avail = cluster.available_matrix()
    n_regions = len(names)
    has_free = free > 0

    # Per-seed early-exit bound: a path can only aggregate GPUs from regions
    # reachable over positive-residual links, so a seed whose reachable free
    # total lands strictly below the incumbent candidate cannot win (equal
    # totals still compete on price and must expand).  Reachability is lazy —
    # computed only once an incumbent exists to prune against.
    adjacency = (avail > 0.0) & has_free[None, :]
    reach_free: Dict[int, int] = {}

    def reachable_free_total(si: int) -> int:
        cached = reach_free.get(si)
        if cached is None:
            reach = np.zeros(n_regions, dtype=bool)
            reach[si] = True
            frontier = reach.copy()
            while frontier.any():
                frontier = adjacency[frontier].any(axis=0) & ~reach
                reach |= frontier
            cached = int(free[reach].sum())
            reach_free[si] = cached
        return cached

    best_cand: Optional[PathCandidate] = None
    for si in range(n_regions):
        free_seed = int(free[si])
        if free_seed < 1:
            continue
        if (
            best_cand is not None
            and min(reachable_free_total(si), k) < best_cand.gpus
        ):
            continue
        visited = np.zeros(n_regions, dtype=bool)
        visited[si] = True
        path_idx: List[int] = [si]
        tail = si
        g = min(free_seed, k)
        b_min = float("inf")
        # Admission heuristic on heterogeneous clusters: evaluate t_comp at
        # the most conservative (slowest) FLOPS a region along the path
        # could grant — slower stages tolerate slower links.  The final
        # build_placement gate re-checks against the actual typed grant.
        f_min = (
            cluster.min_available_flops(names[si], profile.gpu_flops)
            if hetero
            else None
        )
        while len(path_idx) < n_regions and g < k:
            # Highest-bandwidth (residual) outgoing link to a fresh region.
            row = avail[tail]
            cand_mask = has_free & ~visited & (row > 0.0)
            cand_idx = np.flatnonzero(cand_mask)
            if cand_idx.size == 0:
                break
            vals = row[cand_idx]
            top = cand_idx[vals == vals.max()]
            # max by (bandwidth, name): equal-bandwidth ties take the largest name
            nxt = int(top[np.argmax(name_rank[top])])
            b_tmp = min(b_min, float(row[nxt]))
            g_new = min(g + int(free[nxt]), k)
            if hetero:
                f_new = min(
                    f_min,
                    cluster.min_available_flops(
                        names[nxt], profile.gpu_flops
                    ),
                )
                t_cmp = profile.t_comp_hw(g_new, f_new)
            else:
                f_new = None
                t_cmp = profile.t_comp(g_new)
            # Alg. 1 line 13: communication must keep up with compute.
            if act / b_tmp > t_cmp:
                break
            path_idx.append(nxt)
            visited[nxt] = True
            tail = nxt
            b_min, g = b_tmp, g_new
            f_min = f_new

        if g < profile.min_gpus or g < len(path_idx):
            continue
        path = [names[i] for i in path_idx]
        try:
            alloc = allocator(cluster, path, g)
        except ValueError:
            continue
        try:
            placement = build_placement(
                profile, cluster, path, alloc, require_comm_fits_comp=True
            )
        except ValueError:
            continue
        cand = PathCandidate(
            path=tuple(path),
            gpus=g,
            avg_price=average_price(placement, cluster),
            alloc=alloc,
        )
        if (
            best_cand is None
            or cand.gpus > best_cand.gpus
            or (cand.gpus == best_cand.gpus and cand.avg_price < best_cand.avg_price)
        ):
            best_cand = cand

    if best_cand is None:
        return None
    return build_placement(
        profile,
        cluster,
        list(best_cand.path),
        best_cand.alloc,
        require_comm_fits_comp=True,
    )
