"""Model assembly for every assigned architecture family.

One entry point: ``build_model(cfg)`` returns a ``ModelAPI`` with
``init / loss / forward_hidden / init_cache / decode_step``.  Layer stacks
are *scanned* (stacked parameter pytrees + ``jax.lax.scan``) so the compiled
HLO stays one-layer-sized — essential for the 512-device AOT dry-run on CPU.

Scan grouping per family:
  dense (uniform)        : scan over L blocks
  gemma2 (alternating)   : scan over L/2 (local, global) pairs
  moe                    : scan over L blocks (attention + MoE FFN)
  ssm (mamba2)           : scan over L mamba blocks
  hybrid (zamba2)        : scan over L/attn_every groups; a *shared*
                           attention block (one weight set) runs per group
  encdec (seamless)      : encoder scan + decoder scan (self + cross attn)
  vlm (qwen2-vl)         : dense decoder over [vision-embeds ; text tokens]
                           with M-RoPE positions from the frontend stub
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from . import moe as moe_lib
from . import ssm as ssm_lib
from .layers import (
    Params,
    apply_rope,
    attention_apply,
    dense_block_apply,
    embed,
    init_attention,
    init_dense_block,
    init_embedding,
    init_mlp,
    init_rms_norm,
    lm_logits,
    mlp_apply,
    mrope_angles,
    rms_norm,
    rope_angles,
)

ShardFn = Optional[Callable[[jax.Array, str], jax.Array]]


@dataclasses.dataclass(frozen=True)
class ModelCtx:
    """Per-call context: sharding hook, kernel toggle, EP axis info."""

    shard_act: ShardFn = None
    use_kernel: bool = False
    ep_axis: Optional[str] = None
    ep_size: int = 1
    mesh: Any = None


# =========================================================== block callables
def _init_moe_block(key, cfg: ArchConfig, dtype) -> Params:
    ka, km = jax.random.split(key)
    return {
        "ln_attn": init_rms_norm(cfg.d_model, dtype),
        "attn": init_attention(ka, cfg, dtype),
        "ln_mlp": init_rms_norm(cfg.d_model, dtype),
        "moe": moe_lib.init_moe_ffn(km, cfg, dtype),
    }


def _moe_block_apply(p, x, cos, sin, cfg, ctx: ModelCtx, cache=None, cache_pos=None):
    a, new_cache = attention_apply(
        p["attn"], rms_norm(x, p["ln_attn"], cfg.rms_eps), cos, sin, cfg,
        cache=cache, cache_pos=cache_pos, shard_act=ctx.shard_act,
    )
    x = x + a
    y, aux = moe_lib.moe_ffn_apply(
        p["moe"], rms_norm(x, p["ln_mlp"], cfg.rms_eps), cfg,
        ep_axis=ctx.ep_axis if cache is None else None,
        ep_size=ctx.ep_size, mesh=ctx.mesh,
    )
    x = x + y
    if ctx.shard_act is not None:
        x = ctx.shard_act(x, "residual")
    return x, new_cache, aux


# ============================================================== family: LM
def _lm_init(key, cfg: ArchConfig, dtype) -> Params:
    keys = jax.random.split(key, 4)
    p: Params = {"embed": init_embedding(keys[0], cfg, dtype),
                 "ln_f": init_rms_norm(cfg.d_model, dtype)}

    if cfg.family in ("dense", "vlm"):
        if cfg.alternate_local_global:
            n_units = cfg.n_layers // 2

            def unit(k):
                kl, kg = jax.random.split(k)
                return {
                    "local": init_dense_block(kl, cfg, dtype),
                    "global": init_dense_block(kg, cfg, dtype),
                }
        else:
            n_units = cfg.n_layers
            unit = lambda k: init_dense_block(k, cfg, dtype)
    elif cfg.family == "moe":
        n_units = cfg.n_layers
        unit = lambda k: _init_moe_block(k, cfg, dtype)
    elif cfg.family == "ssm":
        n_units = cfg.n_layers
        unit = lambda k: ssm_lib.init_mamba_block(k, cfg, dtype)
    elif cfg.family == "hybrid":
        assert cfg.n_layers % cfg.attn_every == 0
        n_units = cfg.n_layers // cfg.attn_every

        def unit(k):
            ks = jax.random.split(k, cfg.attn_every)
            return jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[ssm_lib.init_mamba_block(kk, cfg, dtype) for kk in ks],
            )

        p["shared_attn"] = init_dense_block(keys[2], cfg, dtype)
    else:
        raise ValueError(cfg.family)

    unit_keys = jax.random.split(keys[1], n_units)
    p["blocks"] = jax.tree.map(
        lambda *xs: jnp.stack(xs), *[unit(k) for k in unit_keys]
    )
    return p


def _positions_angles(cfg: ArchConfig, batch: Dict[str, jax.Array], t: int):
    if cfg.mrope:
        pos3 = batch["positions3"]  # [3, B, T]
        return mrope_angles(pos3, cfg.mrope_sections, cfg.head_dim_, cfg.rope_theta)
    if cfg.family in ("ssm",):
        return None, None
    pos = jnp.arange(t)
    return rope_angles(pos, cfg.head_dim_, cfg.rope_theta)


def _lm_inputs(cfg: ArchConfig, p: Params, batch) -> jax.Array:
    x = embed(p["embed"], batch["tokens"], cfg)
    if cfg.family == "vlm" and "vision_embeds" in batch:
        x = jnp.concatenate([batch["vision_embeds"].astype(x.dtype), x], axis=1)
    return x


def _lm_hidden(
    p: Params, batch, cfg: ArchConfig, ctx: ModelCtx
) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward (train/prefill).  Returns (hidden, aux_loss)."""
    x = _lm_inputs(cfg, p, batch)
    t = x.shape[1]
    cos, sin = _positions_angles(cfg, batch, t)

    if cfg.family in ("dense", "vlm"):
        if cfg.alternate_local_global:
            def body(carry, bp):
                h, aux = carry
                h, _ = dense_block_apply(
                    bp["local"], h, cos, sin, cfg,
                    window=cfg.sliding_window, shard_act=ctx.shard_act,
                )
                h, _ = dense_block_apply(
                    bp["global"], h, cos, sin, cfg, shard_act=ctx.shard_act,
                )
                return (h, aux), None
        else:
            def body(carry, bp):
                h, aux = carry
                h, _ = dense_block_apply(
                    bp, h, cos, sin, cfg, shard_act=ctx.shard_act,
                )
                return (h, aux), None
    elif cfg.family == "moe":
        def body(carry, bp):
            h, aux = carry
            h, _, a = _moe_block_apply(bp, h, cos, sin, cfg, ctx)
            return (h, aux + a), None
    elif cfg.family == "ssm":
        def body(carry, bp):
            h, aux = carry
            h, _ = ssm_lib.mamba_block_apply(
                bp, h, cfg, shard_act=ctx.shard_act, use_kernel=ctx.use_kernel
            )
            return (h, aux), None
    elif cfg.family == "hybrid":
        shared = p["shared_attn"]

        def body(carry, bp):
            h, aux = carry
            h, _ = dense_block_apply(
                shared, h, cos, sin, cfg, shard_act=ctx.shard_act
            )

            def inner(hh, bpi):
                hh, _ = ssm_lib.mamba_block_apply(
                    bpi, hh, cfg, shard_act=ctx.shard_act,
                    use_kernel=ctx.use_kernel,
                )
                return hh, None

            h, _ = jax.lax.scan(inner, h, bp)
            return (h, aux), None
    else:
        raise ValueError(cfg.family)

    # full block remat: backward recomputes each block, so the stash is
    # one residual stream per layer instead of every intermediate
    (x, aux), _ = jax.lax.scan(
        jax.checkpoint(body), (x, jnp.float32(0.0)), p["blocks"]
    )
    return rms_norm(x, p["ln_f"], cfg.rms_eps), aux


# ------------------------------------------------------------ LM: KV caches
def _lm_init_cache(cfg: ArchConfig, batch: int, cache_len: int, dtype):
    hkv, dh = cfg.n_kv_heads, cfg.head_dim_

    def kv(length):
        return {
            "k": jnp.zeros((batch, length, hkv, dh), dtype),
            "v": jnp.zeros((batch, length, hkv, dh), dtype),
        }

    def stack(tree, n):
        return jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape), tree)

    if cfg.family in ("dense", "vlm"):
        if cfg.alternate_local_global:
            w = min(cfg.sliding_window, cache_len)
            return {
                "local": stack(kv(w), cfg.n_layers // 2),
                "global": stack(kv(cache_len), cfg.n_layers // 2),
            }
        return stack(kv(cache_len), cfg.n_layers)
    if cfg.family == "moe":
        return stack(kv(cache_len), cfg.n_layers)
    if cfg.family == "ssm":
        return stack(ssm_lib.init_mamba_cache(cfg, batch, dtype), cfg.n_layers)
    if cfg.family == "hybrid":
        n_groups = cfg.n_layers // cfg.attn_every
        return {
            "mamba": stack(
                stack(ssm_lib.init_mamba_cache(cfg, batch, dtype), cfg.attn_every),
                n_groups,
            ),
            "shared_kv": stack(kv(cache_len), n_groups),
        }
    raise ValueError(cfg.family)


def _lm_decode(
    p: Params, cache, batch, cfg: ArchConfig, ctx: ModelCtx
) -> Tuple[jax.Array, Any]:
    """One-token decode.  batch: {'token': [B,1], 'pos': scalar int32}."""
    tok = batch["token"]
    pos = batch["pos"]
    x = embed(p["embed"], tok, cfg)
    if cfg.mrope:
        pos3 = jnp.broadcast_to(pos, (3, tok.shape[0], 1))
        cos, sin = mrope_angles(pos3, cfg.mrope_sections, cfg.head_dim_, cfg.rope_theta)
    elif cfg.family != "ssm":
        cos, sin = rope_angles(pos[None], cfg.head_dim_, cfg.rope_theta)
    else:
        cos = sin = None

    aux = jnp.float32(0.0)
    if cfg.family in ("dense", "vlm") and cfg.alternate_local_global:
        w = cache["local"]["k"].shape[2]

        def body(h, xs):
            bp, lc, gc = xs
            h, lc2 = dense_block_apply(
                bp["local"], h, cos, sin, cfg, window=cfg.sliding_window,
                cache=lc, cache_pos=jnp.mod(pos, w), shard_act=ctx.shard_act,
            )
            h, gc2 = dense_block_apply(
                bp["global"], h, cos, sin, cfg,
                cache=gc, cache_pos=pos, shard_act=ctx.shard_act,
            )
            return h, (lc2, gc2)

        x, (lc_new, gc_new) = jax.lax.scan(
            body, x, (p["blocks"], cache["local"], cache["global"])
        )
        new_cache = {"local": lc_new, "global": gc_new}
    elif cfg.family in ("dense", "vlm", "moe"):
        is_moe = cfg.family == "moe"

        def body(h, xs):
            bp, kv = xs
            if is_moe:
                h, kv2, _ = _moe_block_apply(
                    bp, h, cos, sin, cfg, ctx, cache=kv, cache_pos=pos
                )
            else:
                h, kv2 = dense_block_apply(
                    bp, h, cos, sin, cfg,
                    cache=kv, cache_pos=pos, shard_act=ctx.shard_act,
                )
            return h, kv2

        x, new_cache = jax.lax.scan(body, x, (p["blocks"], cache))
    elif cfg.family == "ssm":
        def body(h, xs):
            bp, c = xs
            h, c2 = ssm_lib.mamba_block_apply(
                bp, h, cfg, cache=c, shard_act=ctx.shard_act
            )
            return h, c2

        x, new_cache = jax.lax.scan(body, x, (p["blocks"], cache))
    elif cfg.family == "hybrid":
        shared = p["shared_attn"]

        def body(h, xs):
            bp, mc, skv = xs
            h, skv2 = dense_block_apply(
                shared, h, cos, sin, cfg,
                cache=skv, cache_pos=pos, shard_act=ctx.shard_act,
            )

            def inner(hh, xsi):
                bpi, ci = xsi
                hh, ci2 = ssm_lib.mamba_block_apply(bpi, hh, cfg, cache=ci)
                return hh, ci2

            h, mc2 = jax.lax.scan(inner, h, (bp, mc))
            return h, (mc2, skv2)

        x, (mc_new, skv_new) = jax.lax.scan(
            body, x, (p["blocks"], cache["mamba"], cache["shared_kv"])
        )
        new_cache = {"mamba": mc_new, "shared_kv": skv_new}
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x, p["ln_f"], cfg.rms_eps)
    return lm_logits(p["embed"], x, cfg), new_cache


# ======================================================== family: enc-dec
def _init_cross_block(key, cfg: ArchConfig, dtype) -> Params:
    ka, kc, km = jax.random.split(key, 3)
    return {
        "ln_self": init_rms_norm(cfg.d_model, dtype),
        "self": init_attention(ka, cfg, dtype),
        "ln_cross": init_rms_norm(cfg.d_model, dtype),
        "cross": init_attention(kc, cfg, dtype),
        "ln_mlp": init_rms_norm(cfg.d_model, dtype),
        "mlp": init_mlp(km, cfg.d_model, cfg.d_ff, cfg.act, dtype),
    }


def _cross_attention(p, x, memory, cfg, shard_act=None):
    from .layers import attention_full  # local import, no cycle

    b, t, d = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    q = (x @ p["wq"]).reshape(b, t, hq, dh)
    k = (memory @ p["wk"]).reshape(b, memory.shape[1], hkv, dh)
    v = (memory @ p["wv"]).reshape(b, memory.shape[1], hkv, dh)
    if shard_act is not None:
        q, k, v = shard_act(q, "attn_q"), shard_act(k, "attn_kv"), shard_act(v, "attn_kv")
    out = attention_full(q, k, v, causal=False)
    return out.reshape(b, t, hq * dh) @ p["wo"]


def _encdec_init(key, cfg: ArchConfig, dtype) -> Params:
    k0, k1, k2 = jax.random.split(key, 3)
    enc_keys = jax.random.split(k1, cfg.n_enc_layers)
    dec_keys = jax.random.split(k2, cfg.n_layers)
    return {
        "embed": init_embedding(k0, cfg, dtype),
        "enc_blocks": jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[init_dense_block(k, cfg, dtype) for k in enc_keys],
        ),
        "dec_blocks": jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[_init_cross_block(k, cfg, dtype) for k in dec_keys],
        ),
        "ln_enc": init_rms_norm(cfg.d_model, dtype),
        "ln_f": init_rms_norm(cfg.d_model, dtype),
    }


def _encode(p, src_embeds, cfg, ctx: ModelCtx) -> jax.Array:
    """Bidirectional (non-causal) encoder over stub frame embeddings."""
    from .layers import attention_full

    t = src_embeds.shape[1]
    cos, sin = rope_angles(jnp.arange(t), cfg.head_dim_, cfg.rope_theta)

    def enc_body(h, bp):
        xn = rms_norm(h, bp["ln_attn"], cfg.rms_eps)
        ap = bp["attn"]
        b, tt, _ = xn.shape
        hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
        q = apply_rope((xn @ ap["wq"]).reshape(b, tt, hq, dh), cos, sin)
        k = apply_rope((xn @ ap["wk"]).reshape(b, tt, hkv, dh), cos, sin)
        v = (xn @ ap["wv"]).reshape(b, tt, hkv, dh)
        if ctx.shard_act is not None:
            q = ctx.shard_act(q, "attn_q")
            k = ctx.shard_act(k, "attn_kv")
            v = ctx.shard_act(v, "attn_kv")
        a = attention_full(q, k, v, causal=False).reshape(b, tt, hq * dh) @ ap["wo"]
        h = h + a
        h = h + mlp_apply(
            bp["mlp"], rms_norm(h, bp["ln_mlp"], cfg.rms_eps), cfg.act,
            shard_act=ctx.shard_act,
        )
        if ctx.shard_act is not None:
            h = ctx.shard_act(h, "residual")
        return h, None

    x, _ = jax.lax.scan(jax.checkpoint(enc_body), src_embeds, p["enc_blocks"])
    return rms_norm(x, p["ln_enc"], cfg.rms_eps)


def _decode_stack(p, x, memory, cfg, ctx, cos, sin, cache=None, pos=None):
    def body(h, xs):
        bp = xs[0] if cache is not None else xs
        kv = xs[1] if cache is not None else None
        a, kv2 = attention_apply(
            bp["self"], rms_norm(h, bp["ln_self"], cfg.rms_eps), cos, sin,
            cfg, cache=kv, cache_pos=pos, shard_act=ctx.shard_act,
        )
        h = h + a
        h = h + _cross_attention(
            bp["cross"], rms_norm(h, bp["ln_cross"], cfg.rms_eps), memory,
            cfg, shard_act=ctx.shard_act,
        )
        h = h + mlp_apply(
            bp["mlp"], rms_norm(h, bp["ln_mlp"], cfg.rms_eps), cfg.act,
            shard_act=ctx.shard_act,
        )
        if ctx.shard_act is not None:
            h = ctx.shard_act(h, "residual")
        return h, kv2

    if cache is not None:
        x, new_cache = jax.lax.scan(body, x, (p["dec_blocks"], cache))
    else:
        x, new_cache = jax.lax.scan(jax.checkpoint(body), x, p["dec_blocks"])
    return rms_norm(x, p["ln_f"], cfg.rms_eps), new_cache


def _encdec_hidden(p, batch, cfg, ctx) -> Tuple[jax.Array, jax.Array]:
    memory = _encode(p, batch["src_embeds"], cfg, ctx)
    x = embed(p["embed"], batch["tgt_tokens"], cfg)
    t = x.shape[1]
    cos, sin = rope_angles(jnp.arange(t), cfg.head_dim_, cfg.rope_theta)
    x, _ = _decode_stack(p, x, memory, cfg, ctx, cos, sin)
    return x, jnp.float32(0.0)


def _encdec_decode(p, cache, batch, cfg, ctx):
    """cache: {'kv': stacked self-attn cache, 'memory': [B,T_src,D]}."""
    pos = batch["pos"]
    x = embed(p["embed"], batch["token"], cfg)
    cos, sin = rope_angles(pos[None], cfg.head_dim_, cfg.rope_theta)
    x, kv_new = _decode_stack(
        p, x, cache["memory"], cfg, ctx, cos, sin, cache=cache["kv"], pos=pos
    )
    logits = lm_logits(p["embed"], x, cfg)
    return logits, {"kv": kv_new, "memory": cache["memory"]}


# ================================================================ public API
@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ArchConfig
    init: Callable[..., Params]
    hidden: Callable[..., Tuple[jax.Array, jax.Array]]
    init_cache: Callable[..., Any]
    decode_step: Callable[..., Tuple[jax.Array, Any]]

    def loss(self, params, batch, ctx: ModelCtx = ModelCtx(), *, aux_weight=0.01):
        h, aux = self.hidden(params, batch, self.cfg, ctx)
        labels = batch["labels"]
        mask = (labels >= 0).astype(jnp.float32)
        safe = jnp.maximum(labels, 0)
        if self.cfg.family == "vlm":
            # hidden covers [vision ; text]; labels cover text only
            h = h[:, -labels.shape[1]:, :]
        ce = _masked_chunked_xent(self._emb(params), h, safe, mask, self.cfg)
        return ce + aux_weight * aux

    def _emb(self, params):
        return params["embed"]


def _masked_chunked_xent(emb, h, labels, mask, cfg, chunk=1024):
    b, t, d = h.shape
    n_chunks = max(1, t // max(1, min(chunk, t)))
    step_t = t // n_chunks
    hc = h[:, : n_chunks * step_t].reshape(b, n_chunks, step_t, d).swapaxes(0, 1)
    lc = labels[:, : n_chunks * step_t].reshape(b, n_chunks, step_t).swapaxes(0, 1)
    mc = mask[:, : n_chunks * step_t].reshape(b, n_chunks, step_t).swapaxes(0, 1)

    def step(carry, xs):
        hh, ll, mm = xs
        logits = lm_logits(emb, hh, cfg).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ll[..., None], axis=-1)[..., 0]
        tot, cnt = carry
        return (tot + jnp.sum((logz - gold) * mm), cnt + jnp.sum(mm)), None

    (tot, cnt), _ = jax.lax.scan(
        step, (jnp.float32(0.0), jnp.float32(0.0)), (hc, lc, mc)
    )
    return tot / jnp.maximum(cnt, 1.0)


def build_model(cfg: ArchConfig) -> ModelAPI:
    if cfg.family == "encdec":
        return ModelAPI(
            cfg=cfg,
            init=lambda key, dtype=jnp.float32: _encdec_init(key, cfg, dtype),
            hidden=_encdec_hidden,
            init_cache=lambda batch, cache_len, dtype=jnp.float32: {
                "kv": jax.tree.map(
                    lambda x: x,
                    _stack_kv(cfg, batch, cache_len, dtype, cfg.n_layers),
                ),
                "memory": jnp.zeros((batch, cache_len, cfg.d_model), dtype),
            },
            decode_step=_encdec_decode,
        )
    return ModelAPI(
        cfg=cfg,
        init=lambda key, dtype=jnp.float32: _lm_init(key, cfg, dtype),
        hidden=_lm_hidden,
        init_cache=lambda batch, cache_len, dtype=jnp.float32: _lm_init_cache(
            cfg, batch, cache_len, dtype
        ),
        decode_step=_lm_decode,
    )


def _stack_kv(cfg, batch, length, dtype, n):
    kv = {
        "k": jnp.zeros((batch, length, cfg.n_kv_heads, cfg.head_dim_), dtype),
        "v": jnp.zeros((batch, length, cfg.n_kv_heads, cfg.head_dim_), dtype),
    }
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape), kv)
