"""Mixture-of-Experts FFN: shared + routed experts, top-k routing, and
explicit expert-parallel all-to-all (the `model` mesh axis owns the expert
dimension).

The EP data path follows the production pattern: per-shard top-k routing ->
capacity-bounded dispatch (einsum, no [T,E,C] materialization beyond the
per-shard mask) -> ``jax.lax.all_to_all`` to the expert owners -> batched
expert GEMMs -> all_to_all back -> weighted combine.  With ``ep_axis=None``
(single device / smoke tests) the same math runs without collectives.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from .layers import Params, init_mlp, mlp_apply

#: dispatch slots per (token-shard, expert) = tokens * top_k / E * this
CAPACITY_FACTOR = 1.25


def init_moe_ffn(key, cfg: ArchConfig, dtype=jnp.float32) -> Params:
    d, f, e = cfg.d_model, cfg.expert_d_ff, cfg.n_experts
    kr, ke, ks = jax.random.split(key, 3)
    p: Params = {
        "router": (jax.random.normal(kr, (d, e)) * d**-0.5).astype(jnp.float32),
        "w_gate": (jax.random.normal(ke, (e, d, f)) * d**-0.5).astype(dtype),
        "w_up": (jax.random.normal(jax.random.fold_in(ke, 1), (e, d, f)) * d**-0.5).astype(dtype),
        "w_down": (jax.random.normal(jax.random.fold_in(ke, 2), (e, f, d)) * f**-0.5).astype(dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(
            ks, d, cfg.expert_d_ff * cfg.n_shared_experts, cfg.act, dtype
        )
    return p


def _capacity(tokens_per_row: int, cfg: ArchConfig) -> int:
    c = int(tokens_per_row * cfg.top_k / cfg.n_experts * CAPACITY_FACTOR)
    return max(1, c)


def moe_ffn_apply(
    p: Params,
    x: jax.Array,  # [B, S, D]  (global view; S shards over ep_axis)
    cfg: ArchConfig,
    *,
    ep_axis: Optional[str] = None,
    ep_size: int = 1,
    mesh=None,
) -> Tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE FFN.  When ``ep_axis`` is set (training/prefill on
    a model-sharded mesh) the body runs under a partial-manual shard_map:
    tokens split over ``ep_axis``, experts owned by their shard, explicit
    all_to_all both ways.  Otherwise (single device, or single-token decode
    where S=1 cannot shard) the same math runs under GSPMD auto."""
    import jax as _jax
    from jax.sharding import PartitionSpec as P

    from repro.distributed.compat import shard_map

    if ep_axis is None or ep_size <= 1 or x.shape[1] % ep_size != 0:
        return _moe_body(p, x, cfg, None, 1)

    pspecs = {
        "router": P(),
        "w_gate": P(ep_axis, None, None),
        "w_up": P(ep_axis, None, None),
        "w_down": P(ep_axis, None, None),
    }
    p_pass = dict(p)
    if cfg.n_shared_experts:
        pspecs["shared"] = _jax.tree.map(lambda _: P(), p["shared"])
        # replicated manual inputs cross the boundary in f32 so their AD
        # psum is 32-bit (XLA CPU cannot clone 16-bit reducers that carry a
        # Shardy constraint — see DESIGN.md).
        p_pass["shared"] = _jax.tree.map(
            lambda w: w.astype(jnp.float32), p["shared"]
        )

    fn = shard_map(
        lambda pp, xx: _moe_body(pp, xx, cfg, ep_axis, ep_size),
        mesh=mesh,
        in_specs=(pspecs, P(None, ep_axis, None)),
        out_specs=(P(None, ep_axis, None), P()),
        axis_names={ep_axis},
        check_vma=False,
    )
    y, aux = fn(p_pass, x)
    return y.astype(x.dtype), aux


def _moe_body(
    p: Params,
    x: jax.Array,
    cfg: ArchConfig,
    ep_axis: Optional[str],
    ep_size: int,
) -> Tuple[jax.Array, jax.Array]:
    bsz, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = _capacity(s, cfg)

    # ---------------------------------------------------------------- router
    logits = x.astype(jnp.float32) @ p["router"]            # [B,S,E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_ids = jax.lax.top_k(probs, k)                # [B,S,k]
    top_w = top_p / jnp.maximum(
        jnp.sum(top_p, axis=-1, keepdims=True), 1e-9
    )

    # load-balance auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=(0, 1))                       # [E]
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_ids, e), axis=2), axis=(0, 1)
    ) / k
    aux = e * jnp.sum(me * ce)

    # --------------------------------------------------- dispatch (capacity)
    onehot = jax.nn.one_hot(top_ids, e, dtype=jnp.float32)  # [B,S,k,E]
    # position of each (token, choice) within its expert's buffer, per row
    pos = jnp.cumsum(onehot.reshape(bsz, s * k, e), axis=1).reshape(
        bsz, s, k, e
    ) - onehot
    keep = (pos < cap) & (onehot > 0)
    pos_oh = jax.nn.one_hot(
        jnp.sum(pos * onehot, axis=-1).astype(jnp.int32), cap, dtype=jnp.float32
    )                                                       # [B,S,k,C]
    disp = jnp.einsum("bske,bskc->bsec", jnp.where(keep, onehot, 0.0), pos_oh)
    comb = jnp.einsum(
        "bske,bskc,bsk->bsec", jnp.where(keep, onehot, 0.0), pos_oh, top_w
    )

    x_send = jnp.einsum("bsec,bsd->becd", disp, x.astype(jnp.float32))
    x_send = x_send.astype(x.dtype)                         # [B,E,C,D]

    # ------------------------------------------------------------ all_to_all
    if ep_axis is not None and ep_size > 1:
        # [B, E, C, D] -> [B, E/ep, ep*C, D]: every shard receives the slots
        # destined for its local experts from all shards.
        x_recv = jax.lax.all_to_all(
            x_send, ep_axis, split_axis=1, concat_axis=2, tiled=True
        )
    else:
        x_recv = x_send                                     # [B, E_loc, C', D]

    # --------------------------------------------------------- expert GEMMs
    def ffn(xe):  # xe: [B, E_loc, C', D]
        gate = jnp.einsum("becd,edf->becf", xe, p["w_gate"])
        up = jnp.einsum("becd,edf->becf", xe, p["w_up"])
        act = jax.nn.silu(gate) if cfg.act == "silu" else jax.nn.gelu(gate)
        return jnp.einsum("becf,efd->becd", act * up, p["w_down"])

    y_recv = ffn(x_recv)

    if ep_axis is not None and ep_size > 1:
        y_send = jax.lax.all_to_all(
            y_recv, ep_axis, split_axis=2, concat_axis=1, tiled=True
        )
    else:
        y_send = y_recv

    y = jnp.einsum("bsec,becd->bsd", comb, y_send.astype(jnp.float32))

    # --------------------------------------------------------- shared experts
    if cfg.n_shared_experts:
        y = y + mlp_apply(p["shared"], x, cfg.act).astype(jnp.float32)
    aux = aux.astype(jnp.float32)
    if ep_axis is not None and ep_size > 1:
        aux = jax.lax.pmean(aux, ep_axis)
    return y.astype(x.dtype), aux
