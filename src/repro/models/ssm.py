"""Mamba2 (SSD — state-space duality) blocks.  [arXiv:2405.21060]

``ssd_chunked_ref`` is the pure-jnp chunked SSD scan (also the oracle for
``repro.kernels.ssd_scan``): within-chunk attention-like matmuls (MXU
friendly) + an inter-chunk recurrent state pass.

Parameter layout note: the published model fuses (z, x, B, C, dt) into one
``in_proj`` and convolves [x;B;C] jointly.  We store the projections (and the
depthwise conv, which factorizes exactly per channel) *separately* so tensor
parallelism can shard the head-structured pieces (z, x, dt — d_inner/heads
divisible by the mesh) while keeping the small B/C/state pieces replicated.
Mathematically identical to the fused layout.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from .layers import Params, init_rms_norm, rms_norm


# ----------------------------------------------------------------- SSD core
def segsum(da_cs: jax.Array) -> jax.Array:
    """Lower-triangular pairwise decay: L[..., q, k] = exp(cs_q - cs_k), q>=k.
    da_cs: [..., Q] cumulative sum of (dt * A) within a chunk."""
    q = da_cs.shape[-1]
    diff = da_cs[..., :, None] - da_cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, jnp.exp(diff), 0.0)


def ssd_chunked_ref(
    x: jax.Array,     # [B, T, H, P]
    dt: jax.Array,    # [B, T, H]  (post-softplus)
    a: jax.Array,     # [H]        (negative)
    b_: jax.Array,    # [B, T, N]
    c_: jax.Array,    # [B, T, N]
    *,
    chunk: int,
    initial_state: Optional[jax.Array] = None,  # [B, H, P, N]
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.  Returns (y [B,T,H,P], final_state [B,H,P,N])."""
    bsz, t, h, p = x.shape
    n = b_.shape[-1]
    assert t % chunk == 0, (t, chunk)
    nc, q = t // chunk, chunk
    f32 = jnp.float32

    xc = x.reshape(bsz, nc, q, h, p).astype(f32)
    dtc = dt.reshape(bsz, nc, q, h).astype(f32)
    bc = b_.reshape(bsz, nc, q, n).astype(f32)
    cc = c_.reshape(bsz, nc, q, n).astype(f32)
    da = dtc * a.astype(f32)[None, None, None, :]          # [B,C,Q,H]
    da_cs = jnp.cumsum(da, axis=2)                          # [B,C,Q,H]

    # ---- intra-chunk (quadratic within chunk, like masked attention)
    lmat = segsum(jnp.moveaxis(da_cs, -1, -2))              # [B,C,H,Q,Q]
    cb = jnp.einsum("bcqn,bckn->bcqk", cc, bc)              # [B,C,Q,K]
    xdt = xc * dtc[..., None]                               # [B,C,Q,H,P]
    y_diag = jnp.einsum("bcqk,bchqk,bckhp->bcqhp", cb, lmat, xdt)

    # ---- chunk boundary states
    decay_last = jnp.exp(da_cs[:, :, -1:, :] - da_cs)       # [B,C,Q,H]
    states = jnp.einsum("bckn,bckh,bckhp->bchpn", bc, decay_last, xdt)

    # ---- inter-chunk recurrence
    chunk_decay = jnp.exp(jnp.sum(da, axis=2))              # [B,C,H]
    s0 = (
        jnp.zeros((bsz, h, p, n), f32)
        if initial_state is None
        else initial_state.astype(f32)
    )

    def step(s_prev, inputs):
        st, dec = inputs  # [B,H,P,N], [B,H]
        s_new = s_prev * dec[:, :, None, None] + st
        return s_new, s_prev  # emit the state *entering* this chunk

    s_final, s_prev_all = jax.lax.scan(
        step,
        s0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    s_prev = jnp.moveaxis(s_prev_all, 0, 1)                 # [B,C,H,P,N]

    # ---- off-diagonal contribution from carried states
    in_decay = jnp.exp(da_cs)                               # [B,C,Q,H]
    y_off = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", cc, in_decay, s_prev)

    y = (y_diag + y_off).reshape(bsz, t, h, p)
    return y.astype(x.dtype), s_final


def ssd_decode_step(
    state: jax.Array,  # [B, H, P, N]
    x: jax.Array,      # [B, H, P]
    dt: jax.Array,     # [B, H]
    a: jax.Array,      # [H]
    b_: jax.Array,     # [B, N]
    c_: jax.Array,     # [B, N]
) -> Tuple[jax.Array, jax.Array]:
    """Single-token recurrent update.  Returns (y [B,H,P], new_state)."""
    f32 = jnp.float32
    da = dt.astype(f32) * a.astype(f32)[None, :]            # [B,H]
    dec = jnp.exp(da)[:, :, None, None]
    add = (dt.astype(f32)[:, :, None] * x.astype(f32))[..., None] * b_.astype(
        f32
    )[:, None, None, :]
    new_state = state.astype(f32) * dec + add
    y = jnp.einsum("bhpn,bn->bhp", new_state, c_.astype(f32))
    return y.astype(x.dtype), new_state.astype(state.dtype)


# -------------------------------------------------------------- mamba2 block
def init_mamba_block(key, cfg: ArchConfig, dtype=jnp.float32) -> Params:
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    keys = jax.random.split(key, 6)
    s = d**-0.5
    return {
        "ln": init_rms_norm(d, dtype),
        "w_z": (jax.random.normal(keys[0], (d, di)) * s).astype(dtype),
        "w_x": (jax.random.normal(keys[1], (d, di)) * s).astype(dtype),
        "w_b": (jax.random.normal(keys[2], (d, n)) * s).astype(dtype),
        "w_c": (jax.random.normal(keys[3], (d, n)) * s).astype(dtype),
        "w_dt": (jax.random.normal(keys[4], (d, h)) * s).astype(dtype),
        "conv_x": (jax.random.normal(jax.random.fold_in(keys[5], 0), (cfg.ssm_conv, di)) * 0.2).astype(dtype),
        "conv_b": (jax.random.normal(jax.random.fold_in(keys[5], 1), (cfg.ssm_conv, n)) * 0.2).astype(dtype),
        "conv_c": (jax.random.normal(jax.random.fold_in(keys[5], 2), (cfg.ssm_conv, n)) * 0.2).astype(dtype),
        "conv_x_bias": jnp.zeros((di,), dtype),
        "conv_b_bias": jnp.zeros((n,), dtype),
        "conv_c_bias": jnp.zeros((n,), dtype),
        "a_log": jnp.zeros((h,), dtype),           # A = -exp(a_log) = -1
        "d_skip": jnp.ones((h,), dtype),
        "dt_bias": jnp.zeros((h,), dtype),
        "norm": init_rms_norm(di, dtype),
        "out_proj": (jax.random.normal(jax.random.fold_in(keys[5], 3), (di, d)) * di**-0.5).astype(dtype),
    }


def _causal_conv(xin: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over time.  xin: [B, T, C]; w: [K, C]."""
    k = w.shape[0]
    pad = jnp.pad(xin, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xin.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    return jax.nn.silu(out + b[None, None, :])


def _conv_step(hist: jax.Array, new: jax.Array, w: jax.Array, b: jax.Array):
    """Single-token depthwise conv.  hist: [B, K-1, C]; new: [B, 1, C].
    Returns (out [B, C], new_hist [B, K-1, C])."""
    window = jnp.concatenate([hist, new], axis=1)  # [B, K, C]
    out = jnp.einsum(
        "bkc,kc->bc", window.astype(jnp.float32), w.astype(jnp.float32)
    )
    return jax.nn.silu(out + b.astype(jnp.float32)), window[:, 1:, :]


def mamba_block_apply(
    p: Params,
    x: jax.Array,  # [B, T, D]
    cfg: ArchConfig,
    *,
    cache: Optional[Dict[str, jax.Array]] = None,
    shard_act=None,
    use_kernel: bool = False,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Pre-norm residual Mamba2 block.  cache = {'conv_x','conv_b','conv_c',
    'state'} for single-token decode."""
    bsz, t, d = x.shape
    di, n, h, pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    res = x
    x = rms_norm(x, p["ln"], cfg.rms_eps)
    z = x @ p["w_z"]
    xr = x @ p["w_x"]
    br = x @ p["w_b"]
    cr = x @ p["w_c"]
    dt_raw = x @ p["w_dt"]
    dt = jax.nn.softplus(dt_raw + p["dt_bias"][None, None, :].astype(dt_raw.dtype))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))

    new_cache: Optional[Dict[str, jax.Array]] = None
    if cache is None:
        xc = _causal_conv(xr, p["conv_x"], p["conv_x_bias"])
        b_ = _causal_conv(br, p["conv_b"], p["conv_b_bias"])
        c_ = _causal_conv(cr, p["conv_c"], p["conv_c_bias"])
        xs = xc.reshape(bsz, t, h, pd)
        if shard_act is not None:
            xs = shard_act(xs, "ssm_x")
        pad = (-t) % cfg.ssm_chunk  # causality: padded tail never affects y[:t]
        if pad:
            xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dtp = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            b_ = jnp.pad(b_, ((0, 0), (0, pad), (0, 0)))
            c_ = jnp.pad(c_, ((0, 0), (0, pad), (0, 0)))
        else:
            dtp = dt
        if use_kernel:
            from repro.kernels import ops as kops

            y, _ = kops.ssd_scan(xs, dtp, a, b_, c_, chunk=cfg.ssm_chunk)
        else:
            y, _ = ssd_chunked_ref(xs, dtp, a, b_, c_, chunk=cfg.ssm_chunk)
        if pad:
            y, xs = y[:, :t], xs[:, :t]
        y = y + p["d_skip"].astype(y.dtype)[None, None, :, None] * xs
    else:
        xo, hx = _conv_step(cache["conv_x"], xr, p["conv_x"], p["conv_x_bias"])
        bo, hb = _conv_step(cache["conv_b"], br, p["conv_b"], p["conv_b_bias"])
        co, hc = _conv_step(cache["conv_c"], cr, p["conv_c"], p["conv_c_bias"])
        xs = xo.astype(x.dtype).reshape(bsz, h, pd)
        y1, new_state = ssd_decode_step(
            cache["state"], xs, dt[:, 0, :], a,
            bo.astype(x.dtype), co.astype(x.dtype),
        )
        y = (y1 + p["d_skip"].astype(y1.dtype)[None, :, None] * xs)[:, None]
        new_cache = {
            "conv_x": hx.astype(x.dtype),
            "conv_b": hb.astype(x.dtype),
            "conv_c": hc.astype(x.dtype),
            "state": new_state,
        }

    y = y.reshape(bsz, t, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.rms_eps)
    out = y @ p["out_proj"]
    out = res + out
    if shard_act is not None:
        out = shard_act(out, "residual")
    return out, new_cache


def init_mamba_cache(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> Dict[str, jax.Array]:
    k = cfg.ssm_conv - 1
    return {
        "conv_x": jnp.zeros((batch, k, cfg.d_inner), dtype),
        "conv_b": jnp.zeros((batch, k, cfg.ssm_state), dtype),
        "conv_c": jnp.zeros((batch, k, cfg.ssm_state), dtype),
        "state": jnp.zeros(
            (batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), dtype
        ),
    }
