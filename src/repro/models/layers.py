"""Model primitives shared by all architecture families.

Everything is a pure function over explicit parameter pytrees (no framework):
``init_*`` builds params, ``*_apply`` consumes them.  Attention has a
reference jnp path (used by smoke tests, the AOT dry-run, and as the oracle
for the Pallas flash kernel) and an optional fused-kernel path selected via
``repro.kernels.ops``.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

Params = Dict[str, Any]


# ---------------------------------------------------------------------- norms
def init_rms_norm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.zeros((d,), dtype)}


def rms_norm(x: jax.Array, p: Params, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + p["scale"].astype(jnp.float32))).astype(x.dtype)


# ----------------------------------------------------------------------- rope
def rope_angles(
    positions: jax.Array, head_dim: int, theta: float
) -> Tuple[jax.Array, jax.Array]:
    """cos/sin tables for rotary embedding.  positions: [..., T] int32."""
    half = head_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # [..., T, half]
    return jnp.cos(ang), jnp.sin(ang)


def mrope_angles(
    positions3: jax.Array,
    sections: Tuple[int, int, int],
    head_dim: int,
    theta: float,
) -> Tuple[jax.Array, jax.Array]:
    """Qwen2-VL M-RoPE: the rotary half-dim is partitioned into (t, h, w)
    sections, each rotated by its own position stream.
    positions3: [3, ..., T]."""
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    # angles per stream: [3, ..., T, half]
    ang = positions3[..., None].astype(jnp.float32) * freq
    sec_id = jnp.repeat(
        jnp.arange(3), jnp.array(sections), total_repeat_length=half
    )  # [half] -> which stream each frequency uses
    onehot = jax.nn.one_hot(sec_id, 3, dtype=jnp.float32)  # [half, 3]
    ang = jnp.einsum("s...h,hs->...h", ang, onehot)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: [..., T, H, Dh]; cos/sin: [..., T, Dh/2] (broadcast over heads)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :].astype(x.dtype)  # [..., T, 1, half]
    s = sin[..., None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


# ------------------------------------------------------------------ attention
#: full-sequence attention switches to the chunked (flash-structured) jnp
#: path above this many query positions — the [B,H,T,T] score tensor is
#: never materialized, which is what keeps the 32k/500k cells' memory sane.
CHUNKED_ATTN_THRESHOLD = 2048
ATTN_CHUNK = 1024


def repeat_kv(x: jax.Array, n_rep: int) -> jax.Array:
    """[B, T, Hkv, Dh] -> [B, T, Hkv*n_rep, Dh] (GQA broadcast)."""
    if n_rep == 1:
        return x
    b, t, h, d = x.shape
    return jnp.broadcast_to(
        x[:, :, :, None, :], (b, t, h, n_rep, d)
    ).reshape(b, t, h * n_rep, d)


def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    q_offset: int | jax.Array = 0,
    kv_len: Optional[jax.Array] = None,
) -> jax.Array:
    """Reference attention.  q: [B, Tq, Hq, Dh]; k/v: [B, Tk, Hkv, Dh].

    ``q_offset``: absolute position of q[0] (decode); ``kv_len``: number of
    valid cache entries (rest masked).  Also the oracle for kernels/flash.
    """
    b, tq, hq, dh = q.shape
    hkv = k.shape[2]
    k = repeat_kv(k, hq // hkv)
    v = repeat_kv(v, hq // hkv)
    scale = dh ** -0.5
    scores = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    if softcap is not None:
        scores = jnp.tanh(scores / softcap) * softcap
    qpos = jnp.arange(tq) + q_offset  # [Tq]
    kpos = jnp.arange(k.shape[1])     # [Tk]
    mask = jnp.ones((tq, k.shape[1]), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    if kv_len is not None:
        mask &= kpos[None, :] < kv_len
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
    return out


def attention_chunked(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    q_chunk: int = ATTN_CHUNK,
    kv_chunk: int = ATTN_CHUNK,
) -> jax.Array:
    """Flash-structured attention in pure jnp: scan over query chunks, inner
    scan over KV chunks with online-softmax statistics.  Numerically equal to
    ``attention_ref`` but XLA never materializes the [B,H,T,T] scores — the
    fallback path on non-TPU backends (the Pallas kernel is the TPU path).

    q: [B, Tq, Hq, Dh]; k/v: [B, Tk, Hkv, Dh].
    """
    b, tq, hq, dh = q.shape
    tk, hkv = k.shape[1], k.shape[2]
    rep = hq // hkv
    scale = dh ** -0.5
    q_chunk = min(q_chunk, tq)
    kv_chunk = min(kv_chunk, tk)
    pad_q = (-tq) % q_chunk
    pad_k = (-tk) % kv_chunk
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else q
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else k
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else v
    nq, nk = (tq + pad_q) // q_chunk, (tk + pad_k) // kv_chunk
    # [nq, B, qc, Hq, Dh] / [nk, B, kc, Hkv, Dh]
    qs = jnp.moveaxis(qp.reshape(b, nq, q_chunk, hq, dh), 1, 0)
    ks = jnp.moveaxis(kp.reshape(b, nk, kv_chunk, hkv, dh), 1, 0)
    vs = jnp.moveaxis(vp.reshape(b, nk, kv_chunk, hkv, dh), 1, 0)

    def q_block(carry, qi_and_chunk):
        qi, qc = qi_and_chunk  # qc: [B, qcs, Hq, Dh]
        qf = qc.astype(jnp.float32)

        def kv_block(state, ki_and_chunk):
            m, l, acc = state
            ki, kc, vc = ki_and_chunk
            kf = repeat_kv(kc, rep).astype(jnp.float32)
            vf = repeat_kv(vc, rep).astype(jnp.float32)
            s = jnp.einsum("bqhd,bkhd->bhqk", qf, kf) * scale
            if softcap is not None:
                s = jnp.tanh(s / softcap) * softcap
            qpos = qi * q_chunk + jnp.arange(q_chunk)
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)
            mask = (kpos[None, :] < tk) & (qpos[:, None] < tq)
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window is not None:
                mask &= kpos[None, :] > qpos[:, None] - window
            s = jnp.where(mask[None, None], s, -1e30)
            m_cur = jnp.max(s, axis=-1, keepdims=True)
            m_new = jnp.maximum(m, m_cur)
            p = jnp.exp(s - m_new)
            alpha = jnp.exp(m - m_new)
            l_new = alpha * l + jnp.sum(p, axis=-1, keepdims=True)
            acc_new = acc * alpha[..., 0][..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, vf
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hq, q_chunk, 1), -1e30, jnp.float32)
        l0 = jnp.zeros((b, hq, q_chunk, 1), jnp.float32)
        a0 = jnp.zeros((b, hq, q_chunk, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_block, (m0, l0, a0), (jnp.arange(nk), ks, vs)
        )
        out = acc / jnp.maximum(l, 1e-30)
        return carry, jnp.moveaxis(out, 1, 2).astype(q.dtype)  # [B,qcs,Hq,Dh]

    _, outs = jax.lax.scan(jax.checkpoint(q_block), (), (jnp.arange(nq), qs))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, tq + pad_q, hq, dh)
    return out[:, :tq]


def attention_full(
    q, k, v, *, causal=True, window=None, softcap=None
) -> jax.Array:
    """Dispatch: exact reference for short sequences (and the kernel oracle),
    chunked flash-structured path for long ones."""
    if q.shape[1] <= CHUNKED_ATTN_THRESHOLD:
        return attention_ref(
            q, k, v, causal=causal, window=window, softcap=softcap
        )
    return attention_chunked(
        q, k, v, causal=causal, window=window, softcap=softcap
    )


def init_attention(key, cfg: ArchConfig, dtype=jnp.float32) -> Params:
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    k1, k2, k3, k4 = jax.random.split(key, 4)
    scale = d ** -0.5
    p = {
        "wq": (jax.random.normal(k1, (d, hq * dh)) * scale).astype(dtype),
        "wk": (jax.random.normal(k2, (d, hkv * dh)) * scale).astype(dtype),
        "wv": (jax.random.normal(k3, (d, hkv * dh)) * scale).astype(dtype),
        "wo": (jax.random.normal(k4, (hq * dh, d)) * (hq * dh) ** -0.5).astype(dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * dh,), dtype)
        p["bk"] = jnp.zeros((hkv * dh,), dtype)
        p["bv"] = jnp.zeros((hkv * dh,), dtype)
    return p


def attention_apply(
    p: Params,
    x: jax.Array,
    cos: jax.Array,
    sin: jax.Array,
    cfg: ArchConfig,
    *,
    window: Optional[int] = None,
    cache: Optional[Dict[str, jax.Array]] = None,
    cache_pos: Optional[jax.Array] = None,
    shard_act=None,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Self-attention with optional KV cache.

    Training/prefill: cache=None, full-sequence causal attention.
    Decode: x is [B, 1, D]; cache holds [B, S, Hkv, Dh]; the new KV is
    written at ``cache_pos`` and attention spans positions < cache_pos+1.
    """
    b, t, d = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, t, hq, dh)
    k = k.reshape(b, t, hkv, dh)
    v = v.reshape(b, t, hkv, dh)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    if shard_act is not None:
        q, k, v = shard_act(q, "attn_q"), shard_act(k, "attn_kv"), shard_act(v, "attn_kv")

    if cache is None:
        out = attention_full(
            q, k, v, causal=True, window=window,
            softcap=cfg.attn_logit_softcap,
        )
        new_cache = None
    else:
        pos = cache_pos  # scalar int32: index of the new token
        kc = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), pos, axis=1
        )
        vc = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), pos, axis=1
        )
        out = attention_ref(
            q, kc.astype(q.dtype), vc.astype(q.dtype),
            causal=False, window=window,
            softcap=cfg.attn_logit_softcap,
            q_offset=pos, kv_len=pos + 1,
        )
        new_cache = {"k": kc, "v": vc}
    out = out.reshape(b, t, hq * dh)
    return out @ p["wo"], new_cache


# ------------------------------------------------------------------------ mlp
def init_mlp(key, d: int, f: int, act: str, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_up": (jax.random.normal(k1, (d, f)) * d**-0.5).astype(dtype),
        "w_down": (jax.random.normal(k2, (f, d)) * f**-0.5).astype(dtype),
    }
    if act != "gelu_plain":
        p["w_gate"] = (jax.random.normal(k3, (d, f)) * d**-0.5).astype(dtype)
    return p


def mlp_apply(p: Params, x: jax.Array, act: str, shard_act=None) -> jax.Array:
    up = x @ p["w_up"]
    if act == "gelu_plain":
        h = jax.nn.gelu(up)
    else:
        gate = x @ p["w_gate"]
        g = jax.nn.silu(gate) if act == "silu" else jax.nn.gelu(gate)
        h = g * up
    if shard_act is not None:
        # keep the d_ff-sharded hidden sharded through w_down: without this
        # GSPMD gathers the full [B,T,F] f32 gradient (measured ~1 TB/step
        # on gemma2 train_4k — see EXPERIMENTS.md SSPerf)
        h = shard_act(h, "mlp_hidden")
    return h @ p["w_down"]


# ---------------------------------------------------------------- dense block
def init_dense_block(key, cfg: ArchConfig, dtype=jnp.float32) -> Params:
    ka, km = jax.random.split(key)
    return {
        "ln_attn": init_rms_norm(cfg.d_model, dtype),
        "attn": init_attention(ka, cfg, dtype),
        "ln_mlp": init_rms_norm(cfg.d_model, dtype),
        "mlp": init_mlp(km, cfg.d_model, cfg.d_ff, cfg.act, dtype),
    }


def dense_block_apply(
    p: Params,
    x: jax.Array,
    cos: jax.Array,
    sin: jax.Array,
    cfg: ArchConfig,
    *,
    window: Optional[int] = None,
    cache=None,
    cache_pos=None,
    shard_act=None,
) -> Tuple[jax.Array, Any]:
    a, new_cache = attention_apply(
        p["attn"], rms_norm(x, p["ln_attn"], cfg.rms_eps), cos, sin, cfg,
        window=window, cache=cache, cache_pos=cache_pos, shard_act=shard_act,
    )
    x = x + a
    x = x + mlp_apply(
        p["mlp"], rms_norm(x, p["ln_mlp"], cfg.rms_eps), cfg.act,
        shard_act=shard_act,
    )
    if shard_act is not None:
        x = shard_act(x, "residual")
    return x, new_cache


# ------------------------------------------------------------ embedding/head
def init_embedding(key, cfg: ArchConfig, dtype=jnp.float32) -> Params:
    ke, kh = jax.random.split(key)
    v = cfg.padded_vocab
    p = {"table": (jax.random.normal(ke, (v, cfg.d_model)) * 0.02).astype(dtype)}
    if not cfg.tie_embeddings:
        p["head"] = (
            jax.random.normal(kh, (cfg.d_model, v)) * cfg.d_model**-0.5
        ).astype(dtype)
    return p


def embed(p: Params, tokens: jax.Array, cfg: ArchConfig) -> jax.Array:
    x = jnp.take(p["table"], tokens, axis=0)
    if cfg.family == "dense" and cfg.tie_embeddings:  # gemma convention
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    return x


def lm_logits(p: Params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    w = p["table"].T if cfg.tie_embeddings else p["head"]
    logits = x @ w.astype(x.dtype)
    if cfg.final_logit_softcap:
        c = cfg.final_logit_softcap
        logits = jnp.tanh(logits / c) * c
    if cfg.padded_vocab != cfg.vocab:  # mask padded columns out of softmax
        col = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        logits = jnp.where(col < cfg.vocab, logits, -1e30)
    return logits


def chunked_xent(
    p: Params,
    h: jax.Array,
    labels: jax.Array,
    cfg: ArchConfig,
    *,
    chunk: int = 1024,
) -> jax.Array:
    """Mean next-token cross-entropy, computed in T-chunks so the [.., V]
    logits tensor never materializes for the whole sequence."""
    b, t, d = h.shape
    n_chunks = max(1, t // chunk)
    hc = h.reshape(b, n_chunks, t // n_chunks, d).swapaxes(0, 1)
    lc = labels.reshape(b, n_chunks, t // n_chunks).swapaxes(0, 1)

    def step(carry, xs):
        hh, ll = xs
        logits = lm_logits(p, hh, cfg).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ll[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(logz - gold), None

    total, _ = jax.lax.scan(step, jnp.float32(0.0), (hc, lc))
    return total / (b * t)
