"""Deterministic data pipeline.

``SyntheticLM`` generates a stateless, seeded token stream: batch ``i`` is a
pure function of (seed, i), so training is reproducible and restart-safe —
the checkpoint only needs the step counter (the "data cursor").

``ByteDataset`` is a real file-backed corpus with a byte-level vocabulary for
the runnable examples.  Both shard their output across the mesh with
``jax.device_put`` under the batch PartitionSpec.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    """Markov-ish synthetic token stream with a learnable structure (each
    token depends on the previous one plus seeded noise), so loss decreases
    measurably during the example runs."""

    vocab: int
    seq_len: int
    batch: int
    seed: int = 0

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed << 20) + step)
        b, t, v = self.batch, self.seq_len, self.vocab
        base = rng.integers(0, v, size=(b, 1), dtype=np.int64)
        drift = rng.integers(1, 7, size=(b, t), dtype=np.int64)
        noise = (rng.random((b, t)) < 0.05) * rng.integers(0, v, size=(b, t))
        toks = (base + np.cumsum(drift, axis=1) + noise) % v
        tokens = toks.astype(np.int32)
        labels = np.roll(tokens, -1, axis=1)
        labels[:, -1] = tokens[:, 0]
        return {"tokens": tokens, "labels": labels}


class ByteDataset:
    """Byte-level LM dataset over a local file (vocab 256)."""

    def __init__(self, path: str, seq_len: int, batch: int, seed: int = 0):
        with open(path, "rb") as f:
            self.data = np.frombuffer(f.read(), dtype=np.uint8)
        assert len(self.data) > seq_len + 1, "corpus too small"
        self.seq_len, self.batch, self.seed = seq_len, batch, seed

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed << 20) + step)
        starts = rng.integers(0, len(self.data) - self.seq_len - 1, self.batch)
        tokens = np.stack(
            [self.data[s : s + self.seq_len] for s in starts]
        ).astype(np.int32)
        labels = np.stack(
            [self.data[s + 1 : s + self.seq_len + 1] for s in starts]
        ).astype(np.int32)
        return {"tokens": tokens, "labels": labels}


def make_batch_iterator(
    source,
    cfg: ArchConfig,
    mesh: Optional[Mesh] = None,
    batch_spec: Optional[P] = None,
    start_step: int = 0,
) -> Iterator[Dict[str, jax.Array]]:
    """Yields device-resident batches, sharded per the mesh batch spec,
    extended per-family (vlm vision stub / encdec frame stub)."""
    step = start_step
    while True:
        host = source.batch_at(step)
        batch = dict(host)
        if cfg.family == "vlm":
            t = host["tokens"].shape[1]
            tv = max(1, int(t * cfg.vision_frac))
            rng = np.random.default_rng(step)
            batch["tokens"] = host["tokens"][:, : t - tv]
            batch["labels"] = host["labels"][:, : t - tv]
            batch["vision_embeds"] = rng.standard_normal(
                (host["tokens"].shape[0], tv, cfg.d_model)
            ).astype(np.float32) * 0.02
            pos = np.arange(t)[None, None, :]
            batch["positions3"] = np.broadcast_to(
                pos, (3, host["tokens"].shape[0], t)
            ).astype(np.int32)
        elif cfg.family == "encdec":
            rng = np.random.default_rng(step)
            b, t = host["tokens"].shape
            batch = {
                "src_embeds": rng.standard_normal((b, t, cfg.d_model)).astype(
                    np.float32
                )
                * 0.02,
                "tgt_tokens": host["tokens"],
                "labels": host["labels"],
            }
        if mesh is not None and batch_spec is not None:
            def put(name, arr):
                nd = arr.ndim
                if name == "positions3":
                    spec = P(None, batch_spec, None)
                else:
                    spec = P(batch_spec, *([None] * (nd - 1)))
                return jax.device_put(arr, NamedSharding(mesh, spec))

            batch = {k: put(k, v) for k, v in batch.items()}
        yield batch
        step += 1
