from .pipeline import ByteDataset, SyntheticLM, make_batch_iterator  # noqa: F401
